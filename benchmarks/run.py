"""Benchmark driver: one section per paper table/figure + beyond-paper runs.

Usage: PYTHONPATH=src python -m benchmarks.run [--smoke] [--out PATH]

Prints ``name,...`` CSV blocks per benchmark and writes the concurrent-
throughput rows to ``BENCH_concurrent.json`` (machine-readable, git-rev
stamped) so the perf trajectory is tracked across PRs. ``--smoke`` runs only
the concurrent-throughput sweep with tiny parameters (2 clients, 2 iters) —
the CI guard that keeps every bench mode importable and runnable.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def section(title: str) -> None:
    print(f"\n### {title}", flush=True)


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (subprocess.CalledProcessError, OSError):
        return "unknown"


def write_bench_json(rows, path: pathlib.Path) -> None:
    payload = {
        "bench": "concurrent_throughput",
        "git_rev": git_rev(),
        "unix_time": int(time.time()),
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {path}", flush=True)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny-parameter run of the concurrent sweep only")
    parser.add_argument("--sync-write", action="store_true",
                        help="also run the pre-pipeline sync-write baseline "
                             "mode for the write-plane A/B comparison")
    parser.add_argument("--sync-read", action="store_true",
                        help="also run the phased (no-prefetch) sync-read "
                             "baseline mode for the read-plane A/B comparison")
    parser.add_argument("--out", type=pathlib.Path,
                        default=REPO_ROOT / "BENCH_concurrent.json",
                        help="where to write the concurrent-throughput JSON")
    args = parser.parse_args()
    t0 = time.time()

    from benchmarks import concurrent_throughput

    modes = concurrent_throughput.MODES
    if args.sync_write:
        # right after "write", so the A/B pair runs adjacently in time
        i = modes.index("write") + 1
        modes = modes[:i] + (concurrent_throughput.SYNC_WRITE_MODE,) + modes[i:]
    if args.sync_read:
        # right after "stream-read", same adjacency argument
        i = modes.index("stream-read") + 1
        modes = modes[:i] + (concurrent_throughput.SYNC_READ_MODE,) + modes[i:]

    if args.smoke:
        # the smoke sweep covers EVERY mode (including the write-plane modes)
        # so no benchmark path can rot unnoticed in CI
        section("fig3c_concurrent_throughput (smoke: 2 clients, 2 iters)")
        rows = concurrent_throughput.run(n_clients_list=(2,), iters=2, modes=modes)
        for line in concurrent_throughput.to_csv(rows):
            print(line)
        write_bench_json(rows, args.out)
        print(f"\ntotal benchmark time: {time.time() - t0:.1f}s", flush=True)
        return

    section("fig3ab_metadata_overhead (paper Fig. 3a/3b)")
    from benchmarks import metadata_overhead

    for line in metadata_overhead.main():
        print(line)

    section("fig3c_concurrent_throughput (paper Fig. 3c)")
    # best-of-2 per (mode, clients) cell: the checked-in rows feed
    # compare.py's CI regression gate, and single-shot measurements on a
    # busy box flap way past the gate's threshold
    rows = concurrent_throughput.run(modes=modes, repeats=2)
    for line in concurrent_throughput.to_csv(rows):
        print(line)
    write_bench_json(rows, args.out)

    section("serving_throughput (beyond-paper: blob-backed KV + prefix cache)")
    from benchmarks import serving_throughput

    for line in serving_throughput.main(out=REPO_ROOT / "BENCH_serving.json"):
        print(line)

    section("checkpoint_bench (beyond-paper: incremental COW checkpoints)")
    from benchmarks import checkpoint_bench

    for line in checkpoint_bench.main():
        print(line)

    section("roofline (dry-run derived, EXPERIMENTS.md §Roofline)")
    from benchmarks import roofline

    for line in roofline.main():
        print(line)

    print(f"\ntotal benchmark time: {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
