"""Benchmark driver: one section per paper table/figure + beyond-paper runs.

Usage: PYTHONPATH=src python -m benchmarks.run
Prints ``name,...`` CSV blocks per benchmark.
"""

from __future__ import annotations

import time


def section(title: str) -> None:
    print(f"\n### {title}", flush=True)


def main() -> None:
    t0 = time.time()

    section("fig3ab_metadata_overhead (paper Fig. 3a/3b)")
    from benchmarks import metadata_overhead

    for line in metadata_overhead.main():
        print(line)

    section("fig3c_concurrent_throughput (paper Fig. 3c)")
    from benchmarks import concurrent_throughput

    for line in concurrent_throughput.main():
        print(line)

    section("serving_throughput (beyond-paper: paged KV + prefix cache)")
    from benchmarks import serving_throughput

    for line in serving_throughput.main():
        print(line)

    section("checkpoint_bench (beyond-paper: incremental COW checkpoints)")
    from benchmarks import checkpoint_bench

    for line in checkpoint_bench.main():
        print(line)

    section("roofline (dry-run derived, EXPERIMENTS.md §Roofline)")
    from benchmarks import roofline

    for line in roofline.main():
        print(line)

    print(f"\ntotal benchmark time: {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
