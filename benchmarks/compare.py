"""Diff git-rev-stamped benchmark payloads against their previous rows.

Usage: PYTHONPATH=src python -m benchmarks.compare [--json PATH] [--clients N]

Loads the current ``BENCH_concurrent.json`` (working tree), walks the git
history of that file for the most recent committed payload with a different
``git_rev`` stamp, and prints per-(mode, clients) deltas of aggregate
bandwidth — the PR-to-PR perf trajectory check the ROADMAP calls for. The
serving payload ``BENCH_serving.json`` (tokens/s per (mode, sessions)) gets
the same treatment when present. A mode that did not exist in the previous
payload reports ``new`` (never an error — every PR that adds a benchmark
mode hits this case), a mode that disappeared reports ``removed``, and rows
missing expected keys degrade to ``?`` cells.

By default this is a reporting tool (exit status 0 no matter what the deltas
say). With ``--fail-over PCT`` it becomes CI's regression gate: the exit
status is nonzero if any (mode, clients) pair present in BOTH payloads lost
more than PCT% aggregate bandwidth — or any (mode, sessions) pair lost more
than PCT% serving tokens/s — so a read-plane PR can't silently rot the
write-plane numbers (or the serving plane's, or vice versa). New and removed
modes never trip the gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def load_previous(path: pathlib.Path) -> Optional[dict]:
    """Most recent committed payload of ``path`` whose git_rev stamp differs
    from the working-tree payload (i.e. the previous PR's rows)."""
    try:
        current = json.loads(path.read_text())
        rel = path.resolve().relative_to(REPO_ROOT).as_posix()
    except (OSError, ValueError):
        return None  # unreadable, unparsable, or outside the repo (no history)
    try:
        revs = subprocess.run(
            ["git", "log", "--format=%H", "--", rel], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.split()
    except (subprocess.CalledProcessError, OSError):
        return None
    for rev in revs:
        try:
            blob = subprocess.run(
                ["git", "show", f"{rev}:{rel}"], cwd=REPO_ROOT,
                capture_output=True, text=True, check=True,
            ).stdout
            payload = json.loads(blob)
        except (subprocess.CalledProcessError, ValueError):
            continue
        if (payload.get("git_rev"), payload.get("unix_time")) != (
            current.get("git_rev"), current.get("unix_time")
        ):
            return payload
    return None


def _index(payload: dict, count_key: str = "clients") -> Dict[Tuple[str, int], dict]:
    return {
        (r["mode"], r[count_key]): r
        for r in payload.get("rows", [])
        if "mode" in r and count_key in r
    }


def _cell(row: Optional[dict], metric: str = "aggregate_MBps") -> str:
    """Format a row's metric; '?' for schema-mismatched rows."""
    if row is None:
        return "-"
    value = row.get(metric)
    return f"{value:.1f}" if isinstance(value, (int, float)) else "?"


def diff_rows(
    old: dict,
    new: dict,
    clients: Optional[int] = None,
    metric: str = "aggregate_MBps",
    count_key: str = "clients",
) -> List[str]:
    """Human-readable per-(mode, count) deltas of ``metric``."""
    old_idx, new_idx = _index(old, count_key), _index(new, count_key)
    lines = [
        f"comparing {old.get('git_rev', '?')} -> {new.get('git_rev', '?')} "
        f"({metric})",
        f"mode,{count_key},old,new,delta_pct",
    ]
    for key in sorted(new_idx, key=lambda k: (k[0], k[1])):
        mode, n = key
        if clients is not None and n != clients:
            continue
        new_row = new_idx[key]
        old_row = old_idx.get(key)
        if old_row is None:
            # a mode this PR introduced: report it, never crash on it
            lines.append(f"{mode},{n},-,{_cell(new_row, metric)},new")
            continue
        a, b = old_row.get(metric), new_row.get(metric)
        if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
            lines.append(
                f"{mode},{n},{_cell(old_row, metric)},{_cell(new_row, metric)},?"
            )
            continue
        pct = (b - a) / a * 100.0 if a else float("inf")
        lines.append(f"{mode},{n},{a:.1f},{b:.1f},{pct:+.1f}%")
    for key in sorted(set(old_idx) - set(new_idx)):
        if clients is not None and key[1] != clients:
            continue
        lines.append(f"{key[0]},{key[1]},{_cell(old_idx[key], metric)},-,removed")
    return lines


def regressions(
    old: dict,
    new: dict,
    threshold_pct: float,
    metric: str = "aggregate_MBps",
    count_key: str = "clients",
) -> List[Tuple[Tuple[str, int], float]]:
    """(mode, count) pairs present in BOTH payloads whose ``metric`` dropped
    by more than ``threshold_pct`` percent, with the (negative) delta.
    New/removed modes and malformed rows never regress."""
    old_idx, new_idx = _index(old, count_key), _index(new, count_key)
    out: List[Tuple[Tuple[str, int], float]] = []
    for key in sorted(set(old_idx) & set(new_idx)):
        a = old_idx[key].get(metric)
        b = new_idx[key].get(metric)
        if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
            continue
        if a > 0 and (b - a) / a * 100.0 < -threshold_pct:
            out.append((key, (b - a) / a * 100.0))
    return out


def _compare_payload(
    path: pathlib.Path,
    clients: Optional[int],
    fail_over: Optional[float],
    metric: str,
    count_key: str,
) -> Tuple[List[str], int]:
    """Diff + gate one payload file; missing/unparsable files and missing
    history report informationally and never fail."""
    try:
        current = json.loads(path.read_text())
    except (OSError, ValueError) as err:
        return [f"no current benchmark rows at {path}: {err}"], 0
    previous = load_previous(path)
    if previous is None:
        return [f"no previous git-rev-stamped rows for {path}; "
                "nothing to compare"], 0
    lines = diff_rows(
        previous, current, clients=clients, metric=metric, count_key=count_key
    )
    code = 0
    if fail_over is not None:
        for (mode, n), pct in regressions(
            previous, current, fail_over, metric=metric, count_key=count_key
        ):
            lines.append(
                f"REGRESSION {mode},{n}: {pct:+.1f}% exceeds the "
                f"-{fail_over:.0f}% gate"
            )
            code = 1
    return lines, code


def run(argv: Optional[List[str]] = None) -> Tuple[List[str], int]:
    """Full tool body: returns (report lines, exit code)."""
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", type=pathlib.Path,
                        default=REPO_ROOT / "BENCH_concurrent.json")
    parser.add_argument("--serving-json", type=pathlib.Path,
                        default=REPO_ROOT / "BENCH_serving.json",
                        help="serving payload to gate on tok_per_s alongside "
                             "the concurrent payload")
    parser.add_argument("--clients", type=int, default=None,
                        help="restrict the diff to one client count")
    parser.add_argument("--fail-over", type=float, default=None, metavar="PCT",
                        help="exit nonzero if any (mode, clients) pair in both "
                             "payloads lost more than PCT%% aggregate "
                             "bandwidth, or any serving (mode, sessions) pair "
                             "lost more than PCT%% tok/s (the CI gate)")
    args = parser.parse_args(argv)
    lines, code = _compare_payload(
        args.json, args.clients, args.fail_over,
        metric="aggregate_MBps", count_key="clients",
    )
    serving_lines, serving_code = _compare_payload(
        args.serving_json, args.clients, args.fail_over,
        metric="tok_per_s", count_key="sessions",
    )
    return lines + [""] + serving_lines, code or serving_code


def main(argv: Optional[List[str]] = None) -> List[str]:
    return run(argv)[0]


if __name__ == "__main__":
    lines, code = run()
    print("\n".join(lines))
    sys.exit(code)
