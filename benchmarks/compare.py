"""Diff BENCH_concurrent.json against the previous git-rev-stamped rows.

Usage: PYTHONPATH=src python -m benchmarks.compare [--json PATH] [--clients N]

Loads the current ``BENCH_concurrent.json`` (working tree), walks the git
history of that file for the most recent committed payload with a different
``git_rev`` stamp, and prints per-(mode, clients) deltas of aggregate
bandwidth — the PR-to-PR perf trajectory check the ROADMAP calls for. A mode
that did not exist in the previous payload reports ``new`` (never an error —
every PR that adds a benchmark mode hits this case), a mode that disappeared
reports ``removed``, and rows missing expected keys degrade to ``?`` cells.

By default this is a reporting tool (exit status 0 no matter what the deltas
say). With ``--fail-over PCT`` it becomes CI's regression gate: the exit
status is nonzero if any (mode, clients) pair present in BOTH payloads lost
more than PCT% aggregate bandwidth — so a read-plane PR can't silently rot
the write-plane numbers (or vice versa). New and removed modes never trip
the gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def load_previous(path: pathlib.Path) -> Optional[dict]:
    """Most recent committed payload of ``path`` whose git_rev stamp differs
    from the working-tree payload (i.e. the previous PR's rows)."""
    try:
        current = json.loads(path.read_text())
        rel = path.resolve().relative_to(REPO_ROOT).as_posix()
    except (OSError, ValueError):
        return None  # unreadable, unparsable, or outside the repo (no history)
    try:
        revs = subprocess.run(
            ["git", "log", "--format=%H", "--", rel], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.split()
    except (subprocess.CalledProcessError, OSError):
        return None
    for rev in revs:
        try:
            blob = subprocess.run(
                ["git", "show", f"{rev}:{rel}"], cwd=REPO_ROOT,
                capture_output=True, text=True, check=True,
            ).stdout
            payload = json.loads(blob)
        except (subprocess.CalledProcessError, ValueError):
            continue
        if (payload.get("git_rev"), payload.get("unix_time")) != (
            current.get("git_rev"), current.get("unix_time")
        ):
            return payload
    return None


def _index(payload: dict) -> Dict[Tuple[str, int], dict]:
    return {
        (r["mode"], r["clients"]): r
        for r in payload.get("rows", [])
        if "mode" in r and "clients" in r
    }


def _cell(row: Optional[dict]) -> str:
    """Format a row's aggregate bandwidth; '?' for schema-mismatched rows."""
    if row is None:
        return "-"
    value = row.get("aggregate_MBps")
    return f"{value:.1f}" if isinstance(value, (int, float)) else "?"


def diff_rows(old: dict, new: dict, clients: Optional[int] = None) -> List[str]:
    """Human-readable per-(mode, clients) aggregate-bandwidth deltas."""
    old_idx, new_idx = _index(old), _index(new)
    lines = [
        f"comparing {old.get('git_rev', '?')} -> {new.get('git_rev', '?')} "
        f"(aggregate_MBps)",
        "mode,clients,old,new,delta_pct",
    ]
    for key in sorted(new_idx, key=lambda k: (k[0], k[1])):
        mode, n = key
        if clients is not None and n != clients:
            continue
        new_row = new_idx[key]
        old_row = old_idx.get(key)
        if old_row is None:
            # a mode this PR introduced: report it, never crash on it
            lines.append(f"{mode},{n},-,{_cell(new_row)},new")
            continue
        a, b = old_row.get("aggregate_MBps"), new_row.get("aggregate_MBps")
        if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
            lines.append(f"{mode},{n},{_cell(old_row)},{_cell(new_row)},?")
            continue
        pct = (b - a) / a * 100.0 if a else float("inf")
        lines.append(f"{mode},{n},{a:.1f},{b:.1f},{pct:+.1f}%")
    for key in sorted(set(old_idx) - set(new_idx)):
        if clients is not None and key[1] != clients:
            continue
        lines.append(f"{key[0]},{key[1]},{_cell(old_idx[key])},-,removed")
    return lines


def regressions(
    old: dict, new: dict, threshold_pct: float
) -> List[Tuple[Tuple[str, int], float]]:
    """(mode, clients) pairs present in BOTH payloads whose aggregate
    bandwidth dropped by more than ``threshold_pct`` percent, with the
    (negative) delta. New/removed modes and malformed rows never regress."""
    old_idx, new_idx = _index(old), _index(new)
    out: List[Tuple[Tuple[str, int], float]] = []
    for key in sorted(set(old_idx) & set(new_idx)):
        a = old_idx[key].get("aggregate_MBps")
        b = new_idx[key].get("aggregate_MBps")
        if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
            continue
        if a > 0 and (b - a) / a * 100.0 < -threshold_pct:
            out.append((key, (b - a) / a * 100.0))
    return out


def run(argv: Optional[List[str]] = None) -> Tuple[List[str], int]:
    """Full tool body: returns (report lines, exit code)."""
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", type=pathlib.Path,
                        default=REPO_ROOT / "BENCH_concurrent.json")
    parser.add_argument("--clients", type=int, default=None,
                        help="restrict the diff to one client count")
    parser.add_argument("--fail-over", type=float, default=None, metavar="PCT",
                        help="exit nonzero if any (mode, clients) pair in both "
                             "payloads lost more than PCT%% aggregate "
                             "bandwidth (the CI regression gate)")
    args = parser.parse_args(argv)
    try:
        current = json.loads(args.json.read_text())
    except (OSError, ValueError) as err:
        return [f"no current benchmark rows at {args.json}: {err}"], 0
    previous = load_previous(args.json)
    if previous is None:
        return [f"no previous git-rev-stamped rows for {args.json}; "
                "nothing to compare"], 0
    lines = diff_rows(previous, current, clients=args.clients)
    code = 0
    if args.fail_over is not None:
        for (mode, n), pct in regressions(previous, current, args.fail_over):
            lines.append(
                f"REGRESSION {mode},{n}: {pct:+.1f}% exceeds the "
                f"-{args.fail_over:.0f}% gate"
            )
            code = 1
    return lines, code


def main(argv: Optional[List[str]] = None) -> List[str]:
    return run(argv)[0]


if __name__ == "__main__":
    lines, code = run()
    print("\n".join(lines))
    sys.exit(code)
