"""Roofline table from the dry-run sweep (EXPERIMENTS.md §Roofline).

Reads ``dryrun_results.jsonl`` (produced by ``repro.launch.dryrun --all``)
and emits the per-(arch × shape × mesh) three-term roofline with the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and a what-would-help note.
"""

from __future__ import annotations

import json
import os
from typing import List

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.jsonl")

ADVICE = {
    "compute_s": "compute-bound: causal block-skipping (Pallas flash) / lower precision",
    "memory_s": "HBM-bound: fuse softmax chain (Pallas), bf16 intermediates, int8 KV",
    "collective_s": "ICI-bound: fewer FSDP regathers (accum), comm/compute overlap, int8 grads",
}


def main() -> List[str]:
    if not os.path.exists(RESULTS):
        return [f"(skipped: {RESULTS} not found — run repro.launch.dryrun --all first)"]
    out = [
        "arch,shape,mesh,compute_s,memory_s,collective_s,dominant,useful_ratio,mem_GB_per_dev,note"
    ]
    seen = set()
    for line in open(RESULTS):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        if r.get("skipped"):
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,SKIP,,,{r['skipped'][:40]}")
            continue
        if not r.get("ok"):
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,FAIL,,,{r.get('error','')[:40]}")
            continue
        ro = r["roofline"]
        dom = ro["dominant"]
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{ro['compute_s']:.4g},{ro['memory_s']:.4g},{ro['collective_s']:.4g},"
            f"{dom.replace('_s','')},{(ro['useful_flops_ratio'] or 0):.3f},"
            f"{r['bytes_per_device']['total'] / 1e9:.1f},{ADVICE[dom][:52]}"
        )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
