"""Beyond-paper: paged-KV serving engine throughput + prefix-cache savings.

Reduced-config llama on CPU: measures tokens/s with and without shared
prompt prefixes (the COW snapshot-sharing benefit applied to inference), and
the page-pool utilization statistics.
"""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serving.engine import Request, ServingEngine


def run(n_requests=8, max_new=8, shared_prefix_len=16) -> List[dict]:
    cfg = get_config("llama3_2-1b").smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = []
    for mode in ("distinct", "shared_prefix"):
        engine = ServingEngine(cfg, params, max_slots=4, n_pages=512)
        prefix = rng.integers(0, cfg.vocab_size, shared_prefix_len).tolist()
        t0 = time.perf_counter()
        for i in range(n_requests):
            tail = rng.integers(0, cfg.vocab_size, 8).tolist()
            prompt = (prefix if mode == "shared_prefix" else
                      rng.integers(0, cfg.vocab_size, shared_prefix_len).tolist()) + tail
            engine.submit(Request(i, prompt, max_new_tokens=max_new))
        done = engine.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(c.tokens) for c in done.values())
        rows.append(dict(
            mode=mode,
            tok_per_s=toks / dt,
            prefix_hits=sum(c.prefill_skipped_tokens for c in done.values()),
            pages_allocated=engine.alloc.stats["alloc"],
            cow_copies=engine.alloc.stats["cow_copies"],
        ))
    return rows


def main() -> List[str]:
    rows = run()
    out = ["mode,tok_per_s,prefix_hit_tokens,pages_allocated,cow_copies"]
    for r in rows:
        out.append(f"{r['mode']},{r['tok_per_s']:.1f},{r['prefix_hits']},"
                   f"{r['pages_allocated']},{r['cow_copies']}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
