"""Blob-backed KV serving throughput: N concurrent decode sessions over ONE
cluster, shared prefix tier ON vs OFF.

This is the storage plane of inference serving (see docs/SERVING.md): each
session thread runs a :class:`BlobKVClient` against one shared
:class:`BlobKVStore` blob — admit (cluster-wide prefix lookup), modeled
prefill of the non-shared pages, ``writev`` prompt publication, then a
decode loop whose every step compiles the page table into a readv plan
(gather) and publishes each filled page through the async write window.

The A/B is the paper's snapshot sharing: ``shared`` mode uses the
cluster-wide content-addressed prefix directory + the node's shared cache
tier; ``private`` mode disables both, so every session recomputes and
re-stores its prompt prefix and every fetch goes to the data providers
(which is also what drives ReplicaBalancer promotion of the hot prefix).

Outputs tokens/s and TTFT vs. concurrent sessions; rows land git-rev
stamped in ``BENCH_serving.json`` and are regression-gated by
``benchmarks/compare.py`` in CI alongside the concurrent payload.

Usage: PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core import Cluster
from repro.serving.blob_kv import BlobKVClient, BlobKVStore

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

#: modeled prefill compute per non-shared page (what prefix sharing saves)
PREFILL_PAGE_SECONDS = 0.002


def _session_worker(
    client: BlobKVClient,
    prompts: Sequence[Sequence[int]],
    max_new: int,
    page_size: int,
    results: dict,
) -> None:
    """One serving session: sequential requests, each admit → prefill →
    publish → decode → finish. Records per-request TTFT and token counts."""
    T = client.store.page_tokens
    ttfts: List[float] = []
    tokens = 0
    rng = np.random.default_rng(abs(hash(threading.current_thread().name)) % 2**32)
    for prompt in prompts:
        t0 = time.perf_counter()
        while True:
            try:
                seq, shared, fetches = client.admit(prompt)
                break
            except MemoryError:  # pool pressure: brief backoff, retry
                time.sleep(0.001)
        if fetches:
            # shared prefix pages: one vectored read per version group,
            # served from the cache tier when warm
            client.fetch_pages([a for _, a in fetches])
        # modeled prefill compute for the NON-shared pages only
        fresh_pages = -(-(len(prompt) - shared) // T)
        time.sleep(PREFILL_PAGE_SECONDS * fresh_pages)
        # publish fresh FULL prompt pages as one writev (one version)
        full_pages = len(prompt) // T
        payloads = {
            p: rng.integers(0, 256, page_size).astype(np.uint8)
            for p in range(len(seq.shared), full_pages)
        }
        client.publish_prompt(seq, payloads)
        ttfts.append(time.perf_counter() - t0)  # first token ready
        tokens += len(prompt)

        for _ in range(max_new):
            client.append_token(seq)
            # the decode-step gather: page table → one readv plan
            client.gather(seq)
            if seq.length % T == 0:
                idx = seq.length // T - 1
                if seq.page_addr[idx] is None and idx not in client.pending_pages(seq):
                    client.publish_page_async(
                        seq, idx, rng.integers(0, 256, page_size).astype(np.uint8)
                    )
            tokens += 1
        client.finish(seq)
    results[threading.current_thread().name] = (ttfts, tokens)


def run(
    n_sessions_list: Sequence[int] = (2, 4, 8),
    n_requests: int = 4,
    max_new: int = 16,
    prefix_pages: int = 4,
    tail_tokens: int = 6,
    page_tokens: int = 8,
    n_pool_pages: int = 512,
    page_service_seconds: float = 0.002,
    metadata_latency_seconds: float = 0.001,
    seed: int = 0,
    modes: Sequence[str] = ("shared", "private"),
) -> List[dict]:
    """Sweep concurrent session counts in both tier modes. ``seed`` fixes the
    prompt population, so runs are reproducible."""
    rows: List[dict] = []
    for mode in modes:
        shared_tier = mode == "shared"
        for n_sessions in n_sessions_list:
            rng = np.random.default_rng(seed)
            prefix = rng.integers(0, 32000, prefix_pages * page_tokens).tolist()
            cluster = Cluster(
                n_data_providers=4,
                n_metadata_providers=4,
                page_service_seconds=page_service_seconds,
                metadata_latency_seconds=metadata_latency_seconds,
                shared_cache_bytes=(64 << 20) if shared_tier else 0,
            )
            store = BlobKVStore(
                cluster, n_pool_pages, page_bytes=4096, page_tokens=page_tokens
            )
            clients = [
                BlobKVClient(store, use_prefix_cache=shared_tier)
                for _ in range(n_sessions)
            ]
            # every session serves the same system prefix + a unique tail
            prompts = [
                [
                    prefix + rng.integers(0, 32000, tail_tokens).tolist()
                    for _ in range(n_requests)
                ]
                for _ in range(n_sessions)
            ]
            results: dict = {}
            threads = [
                threading.Thread(
                    target=_session_worker,
                    args=(c, p, max_new, store.page_size, results),
                    name=f"serve-{mode}-{i}",
                )
                for i, (c, p) in enumerate(zip(clients, prompts))
            ]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            all_ttft = sorted(x for ttfts, _ in results.values() for x in ttfts)
            total_tokens = sum(tok for _, tok in results.values())
            hits = store.stats["prefix_hits"]
            lookups = hits + store.stats["prefix_misses"]
            rows.append(dict(
                mode=mode,
                sessions=n_sessions,
                tok_per_s=total_tokens / wall,
                ttft_p50_ms=1e3 * all_ttft[len(all_ttft) // 2],
                ttft_max_ms=1e3 * all_ttft[-1],
                prefix_hit_rate=hits / lookups if lookups else 0.0,
                balancer_promotions=(
                    cluster.replica_balancer.rebalance()
                    if cluster.replica_balancer is not None
                    else 0
                ),
                used_slots=store.used_slots,
            ))
    return rows


def to_csv(rows: Sequence[dict]) -> List[str]:
    out = ["mode,sessions,tok_per_s,ttft_p50_ms,ttft_max_ms,prefix_hit_rate"]
    for r in rows:
        out.append(
            f"{r['mode']},{r['sessions']},{r['tok_per_s']:.1f},"
            f"{r['ttft_p50_ms']:.1f},{r['ttft_max_ms']:.1f},"
            f"{r['prefix_hit_rate']:.3f}"
        )
    return out


def write_bench_json(rows: Sequence[dict], path: pathlib.Path) -> None:
    from benchmarks.run import git_rev

    payload = {
        "bench": "serving_throughput",
        "git_rev": git_rev(),
        "unix_time": int(time.time()),
        "rows": list(rows),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {path}", flush=True)


def main(
    smoke: bool = False, out: Optional[pathlib.Path] = None, seed: int = 0
) -> List[str]:
    if smoke:
        rows = run(
            n_sessions_list=(2,), n_requests=2, max_new=4,
            page_service_seconds=0.0005, metadata_latency_seconds=0.0,
            seed=seed,
        )
    else:
        # best-of-2 per cell: single-shot thread timings on a busy box flap
        # past the CI gate's threshold
        best: dict = {}
        for _ in range(2):
            for r in run(seed=seed):
                key = (r["mode"], r["sessions"])
                if key not in best or r["tok_per_s"] > best[key]["tok_per_s"]:
                    best[key] = r
        rows = list(best.values())
    if out is not None:
        write_bench_json(rows, out)
    return to_csv(rows)


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny-parameter run (CI smoke leg)")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--out", type=pathlib.Path,
                        default=REPO_ROOT / "BENCH_serving.json",
                        help="where to write the serving JSON payload")
    args = parser.parse_args()
    print("\n".join(main(smoke=args.smoke, out=args.out, seed=args.seed)))
