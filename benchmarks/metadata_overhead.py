"""Paper Fig. 3(a)/(b): metadata read/write overhead for a single client.

1 TB blob, 64 KB pages, segments 16 KB → 16 MB, with 10/20/40 metadata+data
providers. We report measured wall time of the in-process DHT operations AND
the modeled network completion time under the paper's Grid'5000 cluster
profile (0.1 ms latency, 117.5 MB/s), with the client-side RPC aggregation
(§V.A) applied — aggregation is what makes write cost IMPROVE with more
providers, the paper's key Fig. 3(b) observation.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.paper_sky import CONFIG as SKY
from repro.core import Cluster, count_write_nodes
from repro.core.dht import NODE_WIRE_BYTES


def modeled_time(per_dest_msgs: Dict[int, int], per_dest_bytes: Dict[int, int],
                 client_per_node_s: float = 2e-6, rtt_levels: int = 1) -> float:
    """Completion time: client serialization + aggregated parallel RPCs.

    ``rtt_levels`` models the traversal's level-by-level dependency: a READ
    descends the segment tree (one dependent round-trip per level, paper
    Fig. 2a), while a WRITE ships all nodes in one aggregated round trip
    (§V.A) — this is why the paper's read cost is latency-dominated and its
    write cost improves with provider count."""
    if not per_dest_bytes:
        return 0.0
    total_msgs = sum(per_dest_msgs.values())
    net = max(b / SKY.bandwidth_Bps for b in per_dest_bytes.values())
    return client_per_node_s * total_msgs + rtt_levels * SKY.latency_s + net


def run(n_providers_list=(10, 20, 40), segments=(64 << 10, 256 << 10, 1 << 20, 16 << 20),
        page_size=64 << 10) -> List[dict]:
    # Note: the paper's 16 KB point is sub-page; WRITEs are page-granular
    # (§II), so the sweep starts at one page (64 KB). Sub-page READs are
    # covered by tests/test_core_blob.py via client-side page slicing.
    """Returns rows: provider count × segment size -> metadata r/w cost."""
    rows = []
    blob_size = SKY.blob_size  # 1 TB logical (allocate-on-write: fine in RAM)
    for n_prov in n_providers_list:
        cluster = Cluster(n_data_providers=n_prov, n_metadata_providers=n_prov,
                          shared_cache_bytes=0)
        store = cluster.session()
        handle = store.create(blob_size, page_size)
        rng = np.random.default_rng(0)
        for seg in segments:
            n_pages = seg // page_size
            # --- write: patch a fresh segment ---
            offset = int(rng.integers(0, blob_size // seg)) * seg
            buf = np.ones(seg, dtype=np.uint8)
            cluster.stats.reset()
            t0 = time.perf_counter()
            v = handle.write(buf, offset)
            t_write = time.perf_counter() - t0
            w_msgs = dict(cluster.stats.per_dest_bytes)
            w_model = modeled_time(
                {d: 1 for d in w_msgs}, w_msgs
            )
            n_nodes = count_write_nodes(blob_size // page_size, offset // page_size, n_pages)

            # --- read it back (metadata traversal + page fetch) ---
            cluster.stats.reset()
            t0 = time.perf_counter()
            res = handle.read(offset, seg, version=v)
            t_read = time.perf_counter() - t0
            r_msgs = dict(cluster.stats.per_dest_bytes)
            depth = (blob_size // page_size - 1).bit_length()  # tree height
            r_model = modeled_time({d: 1 for d in r_msgs}, r_msgs, rtt_levels=depth)
            assert res.data.sum() == seg  # all ones

            rows.append(dict(
                providers=n_prov, segment=seg, pages=n_pages, tree_nodes=n_nodes,
                write_wall_us=t_write * 1e6, read_wall_us=t_read * 1e6,
                write_model_ms=w_model * 1e3, read_model_ms=r_model * 1e3,
                aggregated_rpcs=len(w_msgs),
            ))
        cluster.close()
    return rows


def main() -> List[str]:
    rows = run()
    out = ["providers,segment_KB,tree_nodes,write_wall_us,read_wall_us,write_model_ms,read_model_ms"]
    for r in rows:
        out.append(
            f"{r['providers']},{r['segment'] >> 10},{r['tree_nodes']},"
            f"{r['write_wall_us']:.0f},{r['read_wall_us']:.0f},"
            f"{r['write_model_ms']:.3f},{r['read_model_ms']:.3f}"
        )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
