"""Beyond-paper: incremental COW checkpointing cost.

Measures full-save vs incremental-save (dirty-page) time and storage for a
~25M-parameter state, including the snapshot-sharing storage savings across
retained checkpoints — the paper's space-efficiency claim, measured.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Cluster
from repro.storage.checkpoint import BlobCheckpointer


def run(dim=1024, n_layers=12) -> List[dict]:
    key = jax.random.PRNGKey(0)
    state = {
        f"layer{i}": jax.random.normal(jax.random.fold_in(key, i), (dim, dim * 2), jnp.float32)
        for i in range(n_layers)
    }
    cluster = Cluster(n_data_providers=8, n_metadata_providers=8,
                      shared_cache_bytes=0)
    ck = BlobCheckpointer(cluster.session(), state, page_size=1 << 20, keep_last=10)
    rows = []

    t0 = time.perf_counter()
    rec = ck.save(0, state)
    rows.append(dict(kind="full", seconds=time.perf_counter() - t0,
                     dirty_pages=rec.dirty_pages, stored_MB=cluster.storage_bytes() / 1e6))

    # touch 10% of layers (e.g. only the trained adapter / embedding rows)
    state2 = dict(state)
    state2["layer0"] = state["layer0"] + 1.0
    t0 = time.perf_counter()
    rec = ck.save(1, state2)
    rows.append(dict(kind="incremental_10pct", seconds=time.perf_counter() - t0,
                     dirty_pages=rec.dirty_pages, stored_MB=cluster.storage_bytes() / 1e6))

    # unchanged state: pure dedup
    t0 = time.perf_counter()
    rec = ck.save(2, state2)
    rows.append(dict(kind="unchanged", seconds=time.perf_counter() - t0,
                     dirty_pages=rec.dirty_pages, stored_MB=cluster.storage_bytes() / 1e6))

    # restore
    t0 = time.perf_counter()
    ck.restore(1)
    rows.append(dict(kind="restore", seconds=time.perf_counter() - t0,
                     dirty_pages=0, stored_MB=cluster.storage_bytes() / 1e6))
    return rows


def main() -> List[str]:
    rows = run()
    out = ["kind,seconds,dirty_pages,stored_MB"]
    for r in rows:
        out.append(f"{r['kind']},{r['seconds']:.3f},{r['dirty_pages']},{r['stored_MB']:.1f}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
