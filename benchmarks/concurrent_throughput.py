"""Paper Fig. 3(c): per-client bandwidth as concurrency grows.

20 provider nodes (data+metadata), 1 TB blob with 64 KB pages; N concurrent
clients each run a loop of reads (respectively writes) of disjoint segments
within a hot 1 GB window. The paper's claim: per-client bandwidth barely drops
as N grows (lock-free design, only the version-number interaction is
serialized). We measure aggregate and per-client wall-clock bandwidth for
reads, writes, and a mixed R/W workload.

On top of the paper's sweep, three client-side scaling modes:

* ``hot-read`` vs ``cached-read`` — the same hot-window workload (clients
  re-read overlapping windows, the supernovae-detector access pattern) with
  the page cache off vs on. Published-version immutability makes every
  repeat page a RAM hit, so cached-read shows the per-client bandwidth win.
* ``readv`` — each iteration fetches K overlapping segments in ONE vectored
  call: shared pages are deduplicated and each data provider sees one
  aggregated RPC, so ``data_rounds`` collapses vs K separate reads.
* ``skew-read`` vs ``skew-read-primary`` — a zipf-style skewed read workload
  (most reads hammer a few hot pages) against providers with finite service
  bandwidth (``page_service_seconds``). ``skew-read-primary`` pins every
  fetch to the page's primary provider (no hot replication, no spreading):
  aggregate bandwidth collapses to the few providers holding the hot pages.
  ``skew-read`` turns on the :class:`~repro.core.ReplicaBalancer` — hot pages
  are promoted onto extra providers and fetches spread across replicas — and
  recovers the lost aggregate bandwidth (BlobSeer-style dynamic replication).
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.paper_sky import CONFIG as SKY
from repro.core import BalancerConfig, BlobStore

MODES = ("read", "write", "mixed", "hot-read", "cached-read", "readv",
         "skew-read-primary", "skew-read")

#: skew workload shape: HOT_FRACTION of reads land on SKEW_HOT_PAGES pages
SKEW_HOT_PAGES = 2
SKEW_WINDOW_PAGES = 64
HOT_FRACTION = 0.9
#: per-page provider service time modelling finite provider bandwidth —
#: the resource hot-page replication spreads (skew modes only)
SKEW_SERVICE_SECONDS = 0.01
#: promoted copies per hot page: spread each hot page over up to 10 providers
SKEW_MAX_EXTRA_REPLICAS = 9


def _make_store(mode: str, n_providers: int) -> BlobStore:
    if mode.startswith("skew-read"):
        replicate = mode == "skew-read"
        return BlobStore(
            n_data_providers=n_providers, n_metadata_providers=n_providers,
            max_workers=4 * n_providers, cache_bytes=0,
            replica_spread=replicate, hot_replicas=replicate,
            balancer_config=BalancerConfig(
                hot_threshold=4, skew_ratio=1.2, check_interval=16,
                max_extra_replicas=min(SKEW_MAX_EXTRA_REPLICAS, n_providers - 1),
                max_promotions_per_pass=8,
            ),
            page_service_seconds=SKEW_SERVICE_SECONDS,
        )
    # the cache is the measured subject of cached-read; every other mode
    # runs uncached so the paper's baseline stays the baseline
    cache_bytes = (128 << 20) if mode == "cached-read" else 0
    return BlobStore(
        n_data_providers=n_providers, n_metadata_providers=n_providers,
        max_workers=4 * n_providers, cache_bytes=cache_bytes,
    )


def run(n_clients_list=(1, 2, 4, 8, 16), seg_bytes=256 << 10, iters=20,
        page_size=64 << 10, n_providers=20, modes=MODES) -> List[dict]:
    rows = []
    for mode in modes:
        for n_clients in n_clients_list:
            store = _make_store(mode, n_providers)
            # skew modes allocate a window-sized blob: they measure data-plane
            # spreading under provider service limits, so the metadata depth
            # of the paper's 1 TB blob would only add identical CPU to both
            # sides of the comparison
            blob_bytes = (
                SKEW_WINDOW_PAGES * page_size
                if mode.startswith("skew-read")
                else SKY.blob_size
            )
            blob = store.alloc(blob_bytes, page_size)
            # pre-populate the hot window so reads hit real pages; the
            # cache-demo modes re-read a (smaller) fully-prefilled window
            hot = SKY.hot_interval
            if mode in ("hot-read", "cached-read", "readv"):
                hot = min(hot, 64 << 20)
            if mode.startswith("skew-read"):
                hot = SKEW_WINDOW_PAGES * page_size
            init = np.ones(seg_bytes, np.uint8)
            fully_prefilled = mode.startswith("skew-read") or mode in (
                "hot-read", "cached-read", "readv"
            )
            prefill = hot if fully_prefilled else min(hot, seg_bytes * n_clients * iters)
            store.writev(blob, [(off, init[: min(seg_bytes, prefill - off)])
                               for off in range(0, prefill, seg_bytes)])

            barrier = threading.Barrier(n_clients)
            times: List[float] = [0.0] * n_clients
            bytes_moved: List[int] = [0] * n_clients
            # skew modes run longer so the adaptive promotion warmup is a
            # small fraction of the measured window
            mode_iters = iters * 2 if mode.startswith("skew-read") else iters

            def client(cid: int) -> None:
                buf = np.full(seg_bytes, cid + 1, np.uint8)
                rng = np.random.default_rng(1234 + cid)
                moved = 0
                barrier.wait()
                t0 = time.perf_counter()
                for i in range(mode_iters):
                    if mode.startswith("skew-read"):
                        # zipf-style skew: most reads hit a tiny hot page set
                        if rng.random() < HOT_FRACTION:
                            p = int(rng.integers(SKEW_HOT_PAGES))
                        else:
                            p = int(rng.integers(SKEW_WINDOW_PAGES))
                        moved += store.read(blob, None, p * page_size, page_size).data.size
                    elif mode in ("hot-read", "cached-read"):
                        # detector re-read pattern: each client cycles over a
                        # few half-overlapping windows that also overlap its
                        # neighbours' — repeat pages dominate
                        span = max(hot - seg_bytes, page_size)
                        off = ((cid * 3 + (i % 4)) * (seg_bytes // 2)) % span
                        moved += store.read(blob, None, off, seg_bytes).data.size
                    elif mode == "readv":
                        # K overlapping segments fetched in one vectored call
                        span = max(hot - 2 * seg_bytes, page_size)
                        base = ((cid * iters + i) * seg_bytes) % span
                        segs = [(base + k * (seg_bytes // 4), seg_bytes // 2)
                                for k in range(8)]
                        moved += sum(o.size for o in store.readv(blob, None, segs))
                    else:
                        # disjoint segments per client (the paper's workload)
                        off = ((cid * iters + i) * seg_bytes) % hot
                        do_write = mode == "write" or (mode == "mixed" and i % 2 == 1)
                        if do_write:
                            store.write(blob, buf, off)
                            moved += seg_bytes
                        else:
                            moved += store.read(blob, None, off, seg_bytes).data.size
                times[cid] = time.perf_counter() - t0
                bytes_moved[cid] = moved

            store.stats.reset()
            threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            per_client = [b / t / 1e6 for b, t in zip(bytes_moved, times)]  # MB/s
            hits, misses = store.stats.cache_hits, store.stats.cache_misses
            bal = store.replica_balancer
            rows.append(dict(
                mode=mode, clients=n_clients,
                per_client_MBps=float(np.mean(per_client)),
                min_client_MBps=float(np.min(per_client)),
                aggregate_MBps=float(sum(per_client)),
                data_rounds=store.stats.data_rounds,
                cache_hit_rate=hits / (hits + misses) if hits + misses else 0.0,
                promotions=bal.promotions if bal is not None else 0,
            ))
            store.close()
    return rows


CSV_HEADER = ("mode,clients,per_client_MBps,min_client_MBps,aggregate_MBps,"
              "data_rounds,cache_hit_rate,promotions")


def to_csv(rows: Sequence[dict]) -> List[str]:
    out = [CSV_HEADER]
    for r in rows:
        out.append(
            f"{r['mode']},{r['clients']},{r['per_client_MBps']:.1f},"
            f"{r['min_client_MBps']:.1f},{r['aggregate_MBps']:.1f},"
            f"{r['data_rounds']},{r['cache_hit_rate']:.2f},{r['promotions']}"
        )
    return out


def main(n_clients_list=(1, 2, 4, 8, 16), iters: int = 20,
         modes: Optional[Sequence[str]] = None) -> List[str]:
    return to_csv(run(n_clients_list=n_clients_list, iters=iters,
                      modes=tuple(modes) if modes else MODES))


if __name__ == "__main__":
    print("\n".join(main()))
