"""Paper Fig. 3(c): per-client bandwidth as concurrency grows.

20 provider nodes (data+metadata), 1 TB blob with 64 KB pages; N concurrent
clients each run a loop of reads (respectively writes) of disjoint segments
within a hot 1 GB window. The paper's claim: per-client bandwidth barely drops
as N grows (lock-free design, only the version-number interaction is
serialized). We measure aggregate and per-client wall-clock bandwidth for
reads, writes, and a mixed R/W workload.

Every mode runs on the layered API: one :class:`~repro.core.Cluster` per
measurement, client threads driving :class:`~repro.core.BlobHandle` ops. The
legacy modes share ONE session across the client threads (the pre-split
topology those numbers were always measured on); the ``multi-session`` modes
give every client its own :class:`~repro.core.Session`.

On top of the paper's sweep, the client-side scaling modes:

* ``hot-read`` vs ``cached-read`` — the same hot-window workload (clients
  re-read overlapping windows, the supernovae-detector access pattern) with
  the page cache off vs on. Published-version immutability makes every
  repeat page a RAM hit, so cached-read shows the per-client bandwidth win.
* ``degraded-read`` — the cached-read workload on a small 2-way-replicated
  fleet (8 providers) where client 0 kills one provider halfway through the
  measured window: the second half runs on replica fallback while
  background repair re-replicates the lost copies. The resilience headline:
  within 2x of the healthy cached-read aggregate at 16 clients, with the
  ``retries``/``degraded_reads``/``repaired_pages`` columns showing the
  self-healing machinery at work (see ``docs/FAULTS.md``).
* ``degraded-metadata`` — the same cached-read workload with a
  2-way-replicated METADATA plane where client 0 kills one of each node's
  two replica shards halfway through the window: the second half runs on
  metadata replica fallback under the bounded retry policy. Acceptance:
  aggregate read bandwidth >= 0.5x the healthy cached-read run at 16
  clients, with the ``metadata_retries``/``checksum_failures`` columns
  showing the plane degrading instead of hanging.
* ``degraded-node`` — the cached-read workload spread round-robin across a
  4-node :class:`~repro.core.Federation` (one shared substrate, per-node
  cache tiers under the GC epoch/lease protocol). Mid-window client 0
  kills one node outright, partitions a second from the GC coordinator,
  and runs a federated GC pass against the degraded fleet: the pass waits
  out the unreachable nodes' leases instead of blocking on their acks
  (``epoch_stalls``), the partitioned node fences its tiers before its
  next cache serve and reads through uncached (``lease_fences``), and the
  dead node's clients stall until both nodes rejoin at the 3/4 mark.
  Acceptance: aggregate read bandwidth >= 0.5x the healthy cached-read run
  at 16 clients (see ``docs/FAULTS.md``).
* ``readv`` — each iteration fetches K overlapping segments in ONE vectored
  call: shared pages are deduplicated and each data provider sees one
  aggregated RPC, so ``data_rounds`` collapses vs K separate reads.
* ``skew-read`` vs ``skew-read-primary`` — a zipf-style skewed read workload
  (most reads hammer a few hot pages) against providers with finite service
  bandwidth (``page_service_seconds``). ``skew-read-primary`` pins every
  fetch to the page's primary provider (no hot replication, no spreading):
  aggregate bandwidth collapses to the few providers holding the hot pages.
  ``skew-read`` turns on the :class:`~repro.core.ReplicaBalancer` — hot pages
  are promoted onto extra providers and fetches spread across replicas — and
  recovers the lost aggregate bandwidth (BlobSeer-style dynamic replication).
* ``multi-session`` vs ``multi-session-private`` — N sessions on ONE
  cluster, every session sweeping the SAME fresh hot window exactly once
  (the detector fleet reading a newly published sky frame: no intra-session
  re-reads, total cross-session overlap) against service-limited providers.
  ``multi-session`` enables the cluster's shared intra-node cache tier: the
  first session to touch a page fetches it (node-wide single-flight), every
  other session hits RAM — provider traffic for the whole fleet collapses to
  ONE sweep. ``multi-session-private`` gives each session only a private
  cache (which never hits — no session re-reads a page), so all N sessions
  grind through the providers. The A/B is the shared-tier headline:
  ≥1.5× aggregate read bandwidth at 8 sessions.

The read-plane pipeline modes run on a *latency-dominated* grid model —
per-round metadata RTT plus a small per-page provider service time, the
regime where a deep traversal (the paper's TB-scale blobs) hides the data
plane behind metadata rounds:

* ``stream-read`` — per-client sessions doing sequential MB-scale window
  reads through the streaming read plane WITH stride prefetch: as each
  traversal level resolves leaves the ``get_pages`` futures launch
  immediately, and the stride detector keeps the *next* windows' pages
  filling the shared tier while the current read completes. Successive
  reads then hit RAM and the per-read metadata latency is paid once per
  readahead window instead of once per read.
* ``sync-read`` — the SAME workload on ``session(sync_read=True)`` with no
  prefetch: the phased plane (full traversal, then fetch). Off by default;
  enable the A/B with ``python -m benchmarks.run --sync-read``. Headline:
  stream-read >= 1.3x sync-read aggregate bandwidth at 16 clients.
* ``watch-read`` — the supernovae topology: a writer session publishes a
  fresh frame per epoch, a cluster :class:`WatchWarmer` pulls the frame's
  pages into the shared tier on publication, and N watch-driven detector
  sessions read disjoint slices of the frame the moment it publishes. The
  ``first_read_hit_rate`` column isolates the warmer's effect: detectors
  read disjoint slices, so every hit on the first read of an epoch was
  filled by the warmer racing ahead of the detectors.

All rows also record per-read latency percentiles (``p50_ms``/``p99_ms``
across every client's timed operations) next to aggregate bandwidth — the
read-plane pipeline is a latency optimization first, and aggregate MB/s
alone would hide a fat tail.

The write-plane modes measure the overlapped write pipeline under a modeled
grid network — finite provider bandwidth (``page_service_seconds`` per page)
plus a metadata round-trip latency (``metadata_latency_seconds`` per parallel
shard round), the two resources whose overlap is the point of the paper's
decoupled WRITE protocol:

* ``write`` — fine-grain one-page writes through the pipelined ``writev``
  (data puts, version assignment and metadata weaving all overlapped);
* ``sync-write`` — the SAME workload with ``session(sync_write=True)``:
  the pre-pipeline write path (full barrier between stages, defensive page
  copies). The A/B pair in one run is the headline: pipelining buys >=1.5x
  aggregate write bandwidth at 16 clients. Off by default; enable with
  ``python -m benchmarks.run --sync-write``.
* ``stream-write`` — each client streams its writes through
  ``write_async``/``flush`` (bounded in-flight window), so successive
  writes' pipelines ALSO overlap each other (cross-write overlap);
* ``mixed`` — the detector pattern: write a page, then re-read the page you
  just wrote at its assigned version. Runs with the cache on: write-through
  makes the re-reads RAM hits, so the read half costs no provider traffic.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_sky import CONFIG as SKY
from repro.core import (
    BalancerConfig, Cluster, Federation, HealthConfig, PrefetchConfig,
    ProviderFailed, Session,
)

MODES = ("read", "write", "stream-write", "mixed", "hot-read", "cached-read",
         "degraded-read", "degraded-metadata", "degraded-node", "readv",
         "skew-read-primary", "skew-read",
         "multi-session-private", "multi-session",
         "stream-read", "watch-read")
#: the pre-pipeline write path, kept out of the default sweep: enable the
#: A/B with ``python -m benchmarks.run --sync-write``
SYNC_WRITE_MODE = "sync-write"
#: the pre-pipeline (phased, no-prefetch) read path, kept out of the default
#: sweep: enable the A/B with ``python -m benchmarks.run --sync-read``
SYNC_READ_MODE = "sync-read"
WRITE_MODES = ("write", SYNC_WRITE_MODE, "stream-write", "mixed")
MULTI_SESSION_MODES = ("multi-session", "multi-session-private")
#: the streaming-read-plane A/B pair (latency-dominated grid model)
STREAM_READ_MODES = ("stream-read", SYNC_READ_MODE)

#: skew workload shape: HOT_FRACTION of reads land on SKEW_HOT_PAGES pages
SKEW_HOT_PAGES = 2
SKEW_WINDOW_PAGES = 64
HOT_FRACTION = 0.9
#: per-page provider service time modelling finite provider bandwidth —
#: the resource hot-page replication spreads (skew modes only)
SKEW_SERVICE_SECONDS = 0.01
#: promoted copies per hot page: spread each hot page over up to 10 providers
SKEW_MAX_EXTRA_REPLICAS = 9

#: degraded-read topology: a small replicated fleet; client 0 kills one
#: provider halfway through the measured window, so the second half runs on
#: replica fallback + background repair. The A/B against cached-read (same
#: workload, healthy fleet) is the resilience headline: within 2x of healthy
#: aggregate bandwidth at 16 clients
DEGRADED_PROVIDERS = 8
DEGRADED_REPLICATION = 2
#: degraded-metadata topology: the cached-read workload on a 2-way-replicated
#: METADATA plane (consecutive-shard replicas); client 0 kills every even
#: shard halfway through the window — with R=2 that is exactly ONE of each
#: node's two replica homes — so the second half runs on metadata replica
#: fallback under the bounded retry policy. A/B against cached-read: within
#: 2x of healthy aggregate bandwidth at 16 clients, with the
#: ``metadata_retries``/``checksum_failures`` columns showing the plane
#: degrading instead of hanging (see ``docs/FAULTS.md``)
DEGRADED_META_SHARDS = 8
DEGRADED_META_REPLICATION = 2
#: degraded-node topology: the cached-read workload round-robined across a
#: 4-node federation on one shared replicated substrate. Client 0 kills the
#: last node and coordinator-partitions node 1 at the window midpoint, runs
#: a federated GC pass (which waits out the two unreachable leases —
#: ``epoch_stalls``), probes the partitioned node so its post-expiry fence
#: is deterministic (``lease_fences``), and rejoins both nodes at the 3/4
#: mark. A/B against cached-read (same workload, healthy single node):
#: aggregate >= 0.5x at 16 clients
DEGRADED_NODES = 4
#: short lease so the mid-window GC pass waits out the downed nodes in
#: milliseconds, not the 30 s production default
DEGRADED_NODE_LEASE_SECONDS = 0.05
#: keep the killed node in waited-out (lease-expiry) territory rather than
#: declared-dead: the death path (writer recovery, pin reclaim) is the chaos
#: tests' subject, the bench measures the lease protocol's bandwidth cost
DEGRADED_NODE_DEAD_AFTER = 10**6

#: multi-session modes: per-page service time — the provider-side resource a
#: shared cache tier saves (each page crosses the network once per NODE, not
#: once per session)
MULTI_SERVICE_SECONDS = 0.01
#: shared tier budget for the multi-session A/B (ON side)
MULTI_SHARED_CACHE_BYTES = 256 << 20

#: write-plane network model: per-page provider service time (finite data
#: bandwidth) and per-round metadata RTT. Sized so the modeled I/O dominates
#: the client CPU — what the pipeline overlaps is network time, and with
#: near-zero service times the GIL would be the only measured resource.
WRITE_SERVICE_SECONDS = 0.025
METADATA_LATENCY_SECONDS = 0.03
#: write modes patch a window-sized blob (like the skew modes): they measure
#: data/metadata I/O overlap, so the extra tree depth of the paper's 1 TB
#: blob would only add identical CPU to both sides of the A/B
WRITE_WINDOW_PAGES = 1024
#: write_async in-flight window per client (stream-write)
STREAM_WINDOW_PER_CLIENT = 4

#: read-plane pipeline modes: pages per read op (a detector window), and the
#: latency-dominated grid model — a per-round metadata RTT deep traversals
#: multiply, plus a small per-page service time so the data plane is real
#: but not the bottleneck (a saturated provider would cap BOTH sides of the
#: A/B and hide the latency the pipeline removes)
STREAM_READ_PAGES = 8
STREAM_SERVICE_SECONDS = 0.002
STREAM_METADATA_LATENCY = 0.02
#: stride readahead for stream-read: two windows deep, two fills in flight
STREAM_PREFETCH = PrefetchConfig(
    min_run=2, window_pages=4 * STREAM_READ_PAGES, max_inflight=2
)
#: watch-read: frame published per epoch + warmed pages per publication
WATCH_FRAME_PAGES = 256
#: modeled per-epoch detection compute (difference imaging on the frame a
#: detector just read). This is what makes the warmer win real: the writer
#: publishes the NEXT frame while detectors are still computing on the
#: current one, so the warmer fills the shared tier during compute and the
#: next epoch's first reads hit RAM
WATCH_COMPUTE_SECONDS = 0.4
#: shared tier budget for the read-plane modes
STREAM_SHARED_CACHE_BYTES = 512 << 20


def _make_cluster(mode: str, n_providers: int, n_clients: int = 1):
    if mode == "degraded-node":
        return Federation(
            n_nodes=DEGRADED_NODES,
            n_data_providers=DEGRADED_PROVIDERS,
            n_metadata_providers=n_providers,
            page_replication=DEGRADED_REPLICATION,
            max_workers=4 * DEGRADED_PROVIDERS,
            shared_cache_bytes=0,
            lease_seconds=DEGRADED_NODE_LEASE_SECONDS,
            health=HealthConfig(dead_after=DEGRADED_NODE_DEAD_AFTER),
        )
    if mode == "degraded-read":
        return Cluster(
            n_data_providers=DEGRADED_PROVIDERS,
            n_metadata_providers=n_providers,
            max_workers=4 * DEGRADED_PROVIDERS, shared_cache_bytes=0,
            page_replication=DEGRADED_REPLICATION,
        )
    if mode == "degraded-metadata":
        return Cluster(
            n_data_providers=DEGRADED_PROVIDERS,
            n_metadata_providers=DEGRADED_META_SHARDS,
            metadata_replication=DEGRADED_META_REPLICATION,
            max_workers=4 * DEGRADED_PROVIDERS, shared_cache_bytes=0,
            page_replication=DEGRADED_REPLICATION,
        )
    if mode.startswith("skew-read"):
        replicate = mode == "skew-read"
        return Cluster(
            n_data_providers=n_providers, n_metadata_providers=n_providers,
            max_workers=4 * n_providers, shared_cache_bytes=0,
            hot_replicas=replicate,
            balancer_config=BalancerConfig(
                hot_threshold=4, skew_ratio=1.2, check_interval=16,
                max_extra_replicas=min(SKEW_MAX_EXTRA_REPLICAS, n_providers - 1),
                max_promotions_per_pass=8,
            ),
            page_service_seconds=SKEW_SERVICE_SECONDS,
        )
    if mode in MULTI_SESSION_MODES:
        shared = mode == "multi-session"
        return Cluster(
            n_data_providers=n_providers, n_metadata_providers=n_providers,
            max_workers=4 * n_providers,
            shared_cache_bytes=MULTI_SHARED_CACHE_BYTES if shared else 0,
            page_service_seconds=MULTI_SERVICE_SECONDS,
        )
    if mode in WRITE_MODES:
        return Cluster(
            n_data_providers=n_providers, n_metadata_providers=n_providers,
            max_workers=4 * n_providers, shared_cache_bytes=0,
            page_service_seconds=WRITE_SERVICE_SECONDS,
            metadata_latency_seconds=METADATA_LATENCY_SECONDS,
        )
    if mode in STREAM_READ_MODES or mode == "watch-read":
        return Cluster(
            n_data_providers=n_providers, n_metadata_providers=n_providers,
            max_workers=4 * n_providers,
            shared_cache_bytes=STREAM_SHARED_CACHE_BYTES,
            page_service_seconds=STREAM_SERVICE_SECONDS,
            metadata_latency_seconds=STREAM_METADATA_LATENCY,
        )
    return Cluster(
        n_data_providers=n_providers, n_metadata_providers=n_providers,
        max_workers=4 * n_providers, shared_cache_bytes=0,
    )


def _make_sessions(mode: str, cluster: Cluster, n_clients: int) -> List[Session]:
    """Per-client sessions for the multi-session modes; ONE session shared by
    every client thread otherwise (the topology the legacy numbers were
    always measured on)."""
    if mode == "degraded-node":
        # the cached-read workload, round-robined across the federation's
        # nodes: ONE cached session per node shared by that node's clients
        # (mirroring cached-read's one-session topology — the hot window
        # warms once per node, not once per client), but the tiers now live
        # under the GC epoch/lease protocol
        node_sessions = [
            node.session(cache_bytes=128 << 20) for node in cluster.nodes
        ]
        return [
            node_sessions[cid % len(node_sessions)]
            for cid in range(n_clients)
        ]
    if mode in MULTI_SESSION_MODES:
        # OFF side: a private per-session cache (it never hits — the sweep
        # has no intra-session re-reads, which is exactly the point);
        # ON side: no private caches, everything rides the shared tier
        cache = 0 if mode == "multi-session" else (64 << 20)
        return [cluster.session(cache_bytes=cache) for _ in range(n_clients)]
    if mode in STREAM_READ_MODES:
        # per-client sessions: the stride detector is per-session state, and
        # interleaving 16 clients' offsets through one session would shred
        # every stride before it stabilizes
        return [
            cluster.session(
                cache_bytes=0,
                sync_read=(mode == SYNC_READ_MODE),
                prefetch=None if mode == SYNC_READ_MODE else STREAM_PREFETCH,
            )
            for _ in range(n_clients)
        ]
    if mode == "watch-read":
        return [cluster.session(cache_bytes=0) for _ in range(n_clients)]
    if mode.startswith("skew-read"):
        session = cluster.session(
            cache_bytes=0, replica_spread=(mode == "skew-read")
        )
    elif mode in WRITE_MODES:
        session = cluster.session(
            # mixed keeps the cache: its re-reads are the write-through demo
            cache_bytes=(128 << 20) if mode == "mixed" else 0,
            sync_write=(mode == SYNC_WRITE_MODE),
            max_inflight_writes=STREAM_WINDOW_PER_CLIENT * n_clients,
        )
    else:
        # the cache is the measured subject of cached-read (and its
        # mid-crash A/B, degraded-read); every other mode runs uncached so
        # the paper's baseline stays the baseline
        session = cluster.session(
            cache_bytes=(128 << 20)
            if mode in ("cached-read", "degraded-read", "degraded-metadata")
            else 0
        )
    return [session] * n_clients


def run(n_clients_list=(1, 2, 4, 8, 16), seg_bytes=256 << 10, iters=20,
        page_size=64 << 10, n_providers=20, modes=MODES,
        repeats=1) -> List[dict]:
    rows = []
    # client-count-major order: all modes run back-to-back at each client
    # count, so A/B pairs (write vs sync-write, multi-session vs -private)
    # are measured adjacently in time — minutes of thermal/CPU-quota drift
    # between the two sides would otherwise swamp the signal.
    # repeats > 1 measures each (mode, clients) cell that many times and
    # keeps the best row (max aggregate bandwidth): scheduler/thermal
    # interference only ever SLOWS a run, so best-of-N is the standard
    # de-noiser — and the checked-in trajectory rows must be stable enough
    # for compare.py's regression gate to mean something
    for n_clients in n_clients_list:
        for mode in modes:
            best = None
            for _repeat in range(max(repeats, 1)):
                cluster = _make_cluster(mode, n_providers, n_clients)
                sessions = _make_sessions(mode, cluster, n_clients)
                # the federated mode fronts its shared substrate through
                # node 0 for alloc/prefill; everywhere else home IS the
                # cluster
                home = cluster.node(0) if mode == "degraded-node" else cluster
                # the multi-session sweep window: every session reads each page
                # exactly once, so only CROSS-session sharing can save traffic
                multi_window = iters * max(seg_bytes // page_size, 1)
                # skew, multi-session and write modes run longer below; compute
                # iteration counts first so window sizes can depend on them
                if mode in WRITE_MODES:
                    mode_iters = iters * 4
                elif mode == "degraded-node":
                    # long enough that the FIXED fault costs (the lease
                    # wait-out inside the mid-window GC, the fence probe)
                    # amortize — the outage stall itself scales with the
                    # window, so this doesn't dilute the degradation signal
                    mode_iters = iters * 4
                elif mode.startswith("skew-read"):
                    mode_iters = iters * 2
                else:
                    mode_iters = iters
                # stream-read window: every client sweeps its own disjoint
                # sequential region exactly once (stride prefetch can win, page
                # re-reads cannot)
                stream_window = n_clients * mode_iters * STREAM_READ_PAGES
                # skew, multi-session, write and read-plane modes allocate a
                # window-sized blob: they measure data-plane behavior under
                # network service limits, so the metadata depth of the paper's
                # 1 TB blob would only add identical CPU to both sides of their
                # comparisons (the read-plane modes still get a multi-level
                # traversal — the latency the pipeline hides scales with depth)
                if mode.startswith("skew-read"):
                    blob_bytes = SKEW_WINDOW_PAGES * page_size
                elif mode in MULTI_SESSION_MODES:
                    blob_bytes = (1 << (multi_window - 1).bit_length()) * page_size
                elif mode in WRITE_MODES:
                    blob_bytes = WRITE_WINDOW_PAGES * page_size
                elif mode in STREAM_READ_MODES:
                    blob_bytes = (1 << (stream_window - 1).bit_length()) * page_size
                elif mode == "watch-read":
                    blob_bytes = WATCH_FRAME_PAGES * page_size
                else:
                    blob_bytes = SKY.blob_size
                blob = home.alloc(blob_bytes, page_size)
                # pre-populate the hot window so reads hit real pages; the
                # cache-demo modes re-read a (smaller) fully-prefilled window.
                # Read-mode prefill runs through a DEDICATED writer session so
                # its write-through entries cannot pre-warm any measured cache;
                # write modes instead warm up through the measured session on
                # purpose (pool spin-up must not land in the timed window, and
                # mixed never re-reads the prefill versions).
                hot = SKY.hot_interval
                if mode in ("hot-read", "cached-read", "degraded-read",
                            "degraded-metadata", "degraded-node", "readv"):
                    hot = min(hot, 64 << 20)
                if mode.startswith("skew-read"):
                    hot = SKEW_WINDOW_PAGES * page_size
                if mode in MULTI_SESSION_MODES:
                    hot = multi_window * page_size
                if mode in WRITE_MODES:
                    hot = WRITE_WINDOW_PAGES * page_size
                if mode in STREAM_READ_MODES:
                    hot = stream_window * page_size
                init = np.ones(seg_bytes, np.uint8)
                fully_prefilled = (
                    mode.startswith("skew-read")
                    or mode in MULTI_SESSION_MODES
                    or mode in STREAM_READ_MODES
                    or mode in ("hot-read", "cached-read", "degraded-read",
                                "degraded-metadata", "degraded-node", "readv")
                )
                if mode == "watch-read":
                    pass  # frames are published live by the epoch writer thread
                elif mode not in WRITE_MODES:
                    writer = home.session(cache_bytes=0)
                    prefill = hot if fully_prefilled else min(hot, seg_bytes * n_clients * iters)
                    writer.open(blob).writev(
                        [(off, init[: min(seg_bytes, prefill - off)])
                         for off in range(0, prefill, seg_bytes)]
                    )
                    writer.close()
                elif mode == "stream-write":
                    # warm the lazily-spawned worker + writer pools so the timed
                    # window doesn't pay thread creation
                    sh = sessions[0].open(blob)
                    for p in range(2 * n_clients):
                        sh.write_async(init[:page_size], p * page_size)
                    sessions[0].flush()
                else:
                    sessions[0].open(blob).writev(
                        [(p * page_size, init[:page_size])
                         for p in range(2 * n_clients)]
                    )

                barrier = threading.Barrier(n_clients)
                times: List[float] = [0.0] * n_clients
                bytes_moved: List[int] = [0] * n_clients
                #: per-client per-op wall-clock latencies (p50/p99 columns)
                latencies: List[List[float]] = [[] for _ in range(n_clients)]
                #: watch-read only: (hits, misses) of each client's FIRST read of
                #: every fresh frame — the warmer-attribution metric
                first_reads: List[List[int]] = [[0, 0] for _ in range(n_clients)]
                # (mode_iters was computed above, before the window sizing:
                # skew modes run longer so the adaptive promotion warmup is a
                # small fraction of the measured window; write modes longer
                # still — short windows never reach queueing steady state)

                # watch-read topology: one telescope writer session publishes a
                # frame per epoch, the cluster warmer pulls it into the shared
                # tier on publication, detectors wake on their version watch and
                # then spend WATCH_COMPUTE_SECONDS "detecting" on the frame they
                # read. The epoch barrier (writer + detectors) releases the
                # writer the moment every detector has WOKEN on the current
                # frame, so the next frame publishes — and warms — while the
                # fleet computes; it also keeps a fast writer from running the
                # detectors out of RAM
                warmer = None
                writer_thread = None
                epoch_barrier = None
                if mode == "watch-read":
                    warmer = cluster.warm_on_publish(blob, top_pages=WATCH_FRAME_PAGES)
                    epoch_barrier = threading.Barrier(n_clients + 1)
                    frame = np.ones(WATCH_FRAME_PAGES * page_size, np.uint8)

                    def frame_writer() -> None:
                        wsess = cluster.session(cache_bytes=0)
                        whandle = wsess.open(blob)
                        for _epoch in range(mode_iters):
                            # writev surrenders its buffer: hand over a copy
                            whandle.write(frame.copy(), 0)
                            epoch_barrier.wait()  # detectors woke on this frame
                        wsess.close()

                    writer_thread = threading.Thread(target=frame_writer)

                def client(cid: int) -> None:
                    handle = sessions[cid].open(blob)
                    watch = handle.watch(start_version=0) if mode == "watch-read" else None
                    lat = latencies[cid]
                    buf = np.full(seg_bytes, cid + 1, np.uint8)
                    # write modes hand out an OWNED page-sized buffer: writev
                    # freezes it on first use and stores zero-copy views of it
                    wbuf = np.full(page_size, cid + 1, np.uint8)
                    inflight: List = []
                    rng = np.random.default_rng(1234 + cid)
                    moved = 0
                    barrier.wait()
                    t0 = time.perf_counter()
                    for i in range(mode_iters):
                        t_op = time.perf_counter()
                        if mode.startswith("skew-read"):
                            # zipf-style skew: most reads hit a tiny hot page set
                            if rng.random() < HOT_FRACTION:
                                p = int(rng.integers(SKEW_HOT_PAGES))
                            else:
                                p = int(rng.integers(SKEW_WINDOW_PAGES))
                            moved += handle.read(p * page_size, page_size).data.size
                        elif mode in MULTI_SESSION_MODES:
                            # every session sweeps the SAME window once, phase-
                            # staggered (each detector starts at a different sky
                            # region of one freshly published frame): zero intra-
                            # session re-reads, total cross-session overlap
                            phase = cid * max(mode_iters // max(n_clients, 1), 1)
                            seg = (i + phase) % mode_iters
                            moved += handle.read(seg * seg_bytes, seg_bytes).data.size
                        elif mode in ("hot-read", "cached-read",
                                      "degraded-read", "degraded-metadata",
                                      "degraded-node"):
                            # detector re-read pattern: each client cycles over a
                            # few half-overlapping windows that also overlap its
                            # neighbours' — repeat pages dominate
                            if (mode == "degraded-read" and cid == 0
                                    and i == mode_iters // 2):
                                # one of the fleet crashes mid-measurement:
                                # reads keep completing through replica
                                # fallback while background repair re-
                                # replicates (degraded_reads/repaired columns)
                                cluster.provider_manager.fail_provider(0)
                            if (mode == "degraded-metadata" and cid == 0
                                    and i == mode_iters // 2):
                                # every even metadata shard crashes mid-
                                # measurement — exactly one of each node's
                                # two consecutive replica homes. Reads keep
                                # completing through metadata replica
                                # fallback under the bounded retry policy
                                # (metadata_retries column)
                                for sid in range(0, DEGRADED_META_SHARDS, 2):
                                    cluster.metadata.fail_shard(sid)
                            if (mode == "degraded-node" and cid == 0
                                    and i == mode_iters // 2):
                                # a quarter of the fleet drops mid-window:
                                # the last node dies outright (its clients
                                # stall until rejoin) and node 1 loses only
                                # its coordinator link. A federated GC pass
                                # then runs against the degraded fleet — it
                                # waits out the two unreachable leases
                                # (epoch_stalls) instead of blocking on
                                # their acks forever
                                cluster.apply_node_fault(
                                    DEGRADED_NODES - 1, "kill"
                                )
                                cluster.apply_node_fault(1, "partition")
                                cluster.gc(
                                    blob,
                                    keep_versions=[handle.latest_published()],
                                )
                                # the GC pass just waited node 1's lease
                                # out, so its next read MUST fence (purge
                                # its tiers — lease_fences) before serving
                                # and then read through uncached; probe it
                                # so the fence lands deterministically even
                                # when no measured client is on node 1
                                probe = cluster.node(1).session(cache_bytes=0)
                                try:
                                    probe.open(blob).read(0, page_size)
                                finally:
                                    probe.close()
                            if (mode == "degraded-node" and cid == 0
                                    and i == (3 * mode_iters) // 4):
                                cluster.apply_node_fault(
                                    DEGRADED_NODES - 1, "recover"
                                )
                                cluster.apply_node_fault(1, "recover")
                            span = max(hot - seg_bytes, page_size)
                            off = ((cid * 3 + (i % 4)) * (seg_bytes // 2)) % span
                            if mode == "degraded-node":
                                # a client whose node is down idles until
                                # the chaos client rejoins it (bounded so a
                                # rejoin bug can't hang the run)
                                deadline = time.perf_counter() + 60.0
                                while True:
                                    try:
                                        moved += handle.read(
                                            off, seg_bytes
                                        ).data.size
                                        break
                                    except ProviderFailed:
                                        if time.perf_counter() > deadline:
                                            raise
                                        time.sleep(0.002)
                            else:
                                moved += handle.read(off, seg_bytes).data.size
                        elif mode == "readv":
                            # K overlapping segments fetched in one vectored call
                            span = max(hot - 2 * seg_bytes, page_size)
                            base = ((cid * iters + i) * seg_bytes) % span
                            segs = [(base + k * (seg_bytes // 4), seg_bytes // 2)
                                    for k in range(8)]
                            moved += sum(o.size for o in handle.readv(segs))
                        elif mode in WRITE_MODES:
                            # fine-grain one-page writes, disjoint per client
                            # until offsets wrap the window (16 clients x 80
                            # iters > 1024 pages — COW versioning makes the
                            # overlap harmless); page is the patch size, so data
                            # puts and metadata weaving have comparable network
                            # cost — the overlap being measured
                            off = ((cid * mode_iters + i) % WRITE_WINDOW_PAGES) * page_size
                            if mode == "stream-write":
                                inflight.append(handle.write_async(wbuf, off))
                            else:
                                v = handle.write(wbuf, off)
                                if mode == "mixed":
                                    # re-read what we just wrote: a write-through
                                    # cache hit, no provider round-trip (but the
                                    # snapshot is only readable once in-order
                                    # publication reaches it)
                                    handle.wait_for_version(v)
                                    moved += handle.read(off, page_size, version=v).data.size
                            moved += page_size
                        elif mode in STREAM_READ_MODES:
                            # sequential disjoint MB-scale windows per client —
                            # the access pattern the stride prefetcher locks onto
                            # (and the phased baseline pays full latency for)
                            off = (cid * mode_iters + i) * STREAM_READ_PAGES * page_size
                            moved += handle.read(
                                off, STREAM_READ_PAGES * page_size
                            ).data.size
                        elif mode == "watch-read":
                            # detector: wake on the fresh frame's publication,
                            # release the writer (next frame publishes + warms
                            # while we work), read THIS client's disjoint slice —
                            # detectors share no pages, so every first-read hit
                            # was filled by the warmer — then "detect" on it
                            target = i + 1
                            while True:
                                v = watch.next(timeout=120)
                                assert v is not None, "frame writer stalled"
                                if v >= target:
                                    break
                            epoch_barrier.wait()
                            slice_pages = max(WATCH_FRAME_PAGES // n_clients, 1)
                            base = cid * slice_pages * page_size
                            sess_stats = sessions[cid].stats
                            first = True
                            with handle.at(target) as snap:
                                for p0 in range(0, slice_pages, STREAM_READ_PAGES):
                                    n_pg = min(STREAM_READ_PAGES, slice_pages - p0)
                                    h0 = sess_stats.cache_hits
                                    m0 = sess_stats.cache_misses
                                    t_read = time.perf_counter()
                                    moved += snap.read(
                                        base + p0 * page_size, n_pg * page_size
                                    ).size
                                    lat.append(time.perf_counter() - t_read)
                                    if first:
                                        first_reads[cid][0] += sess_stats.cache_hits - h0
                                        first_reads[cid][1] += sess_stats.cache_misses - m0
                                        first = False
                            time.sleep(WATCH_COMPUTE_SECONDS)  # detection compute
                        else:
                            # disjoint segments per client (the paper's workload)
                            off = ((cid * iters + i) * seg_bytes) % hot
                            moved += handle.read(off, seg_bytes).data.size
                        if mode != "watch-read":
                            # per-op latency (watch-read recorded per read above,
                            # excluding the publication wait)
                            lat.append(time.perf_counter() - t_op)
                    for fut in inflight:
                        fut.result()  # join OWN stream only (flush joins a session)
                    times[cid] = time.perf_counter() - t0
                    bytes_moved[cid] = moved

                cluster.stats.reset()
                if mode == "degraded-node":
                    # cache traffic lands on each node's own stats; the
                    # substrate + lease counters land on the federation's
                    for fed_node in cluster.nodes:
                        fed_node.stats.reset()
                threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
                if writer_thread is not None:
                    writer_thread.start()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                if writer_thread is not None:
                    writer_thread.join()
                per_client = [b / t / 1e6 for b, t in zip(bytes_moved, times)]  # MB/s
                hits, misses = cluster.stats.cache_hits, cluster.stats.cache_misses
                data_rounds = cluster.stats.data_rounds
                if mode == "degraded-node":
                    # per-node traffic (cache tiers, data rounds) aggregates on
                    # each node's own stats, not the federation's
                    hits += sum(n.stats.cache_hits for n in cluster.nodes)
                    misses += sum(n.stats.cache_misses for n in cluster.nodes)
                    data_rounds += sum(n.stats.data_rounds for n in cluster.nodes)
                bal = getattr(cluster, "replica_balancer", None)
                wbytes = list(cluster.stats.write_bytes_snapshot().values())
                all_lat = [l for per_client_lat in latencies for l in per_client_lat]
                f_hits = sum(f[0] for f in first_reads)
                f_misses = sum(f[1] for f in first_reads)
                row = dict(
                    mode=mode, clients=n_clients,
                    per_client_MBps=float(np.mean(per_client)),
                    min_client_MBps=float(np.min(per_client)),
                    aggregate_MBps=float(sum(per_client)),
                    data_rounds=data_rounds,
                    cache_hit_rate=hits / (hits + misses) if hits + misses else 0.0,
                    promotions=bal.promotions if bal is not None else 0,
                    # per-destination write skew (max/mean): 1.0 = perfectly
                    # balanced placement, >>1 = write hot-spotting
                    write_skew=float(max(wbytes) / np.mean(wbytes)) if wbytes else 0.0,
                    # per-op latency percentiles across every client's timed ops
                    p50_ms=float(np.percentile(all_lat, 50) * 1e3) if all_lat else 0.0,
                    p99_ms=float(np.percentile(all_lat, 99) * 1e3) if all_lat else 0.0,
                    # watch-read: hit rate of each epoch's FIRST read — hits a
                    # detector could only have gotten from the publish warmer
                    first_read_hit_rate=(
                        f_hits / (f_hits + f_misses) if f_hits + f_misses else 0.0
                    ),
                    # self-healing counters (degraded-read is their showcase;
                    # every mode records them — nonzero elsewhere means the
                    # run itself hit trouble)
                    retries=cluster.stats.retries,
                    replica_fallbacks=cluster.stats.replica_fallbacks,
                    degraded_reads=cluster.stats.degraded_reads,
                    repaired_pages=cluster.stats.repaired_pages,
                    # metadata-plane fault counters (degraded-metadata is
                    # their showcase; nonzero elsewhere means real trouble)
                    metadata_retries=cluster.stats.metadata_retries,
                    checksum_failures=cluster.stats.checksum_failures,
                    # federated-GC lease counters (degraded-node is their
                    # showcase; zero on every standalone-cluster mode)
                    lease_fences=cluster.stats.lease_fences,
                    epoch_stalls=cluster.stats.epoch_stalls,
                )
                cluster.close()
                if best is None or row["aggregate_MBps"] >= best["aggregate_MBps"]:
                    best = row
            rows.append(best)
    # present rows mode-major (the historical JSON/CSV layout) regardless of
    # the execution order above
    order = {m: i for i, m in enumerate(modes)}
    rows.sort(key=lambda r: (order[r["mode"]], r["clients"]))
    return rows


CSV_HEADER = ("mode,clients,per_client_MBps,min_client_MBps,aggregate_MBps,"
              "data_rounds,cache_hit_rate,promotions,write_skew,"
              "p50_ms,p99_ms,first_read_hit_rate,"
              "retries,replica_fallbacks,degraded_reads,repaired_pages,"
              "metadata_retries,checksum_failures,lease_fences,epoch_stalls")


def to_csv(rows: Sequence[dict]) -> List[str]:
    out = [CSV_HEADER]
    for r in rows:
        out.append(
            f"{r['mode']},{r['clients']},{r['per_client_MBps']:.1f},"
            f"{r['min_client_MBps']:.1f},{r['aggregate_MBps']:.1f},"
            f"{r['data_rounds']},{r['cache_hit_rate']:.2f},{r['promotions']},"
            f"{r.get('write_skew', 0.0):.2f},{r.get('p50_ms', 0.0):.1f},"
            f"{r.get('p99_ms', 0.0):.1f},{r.get('first_read_hit_rate', 0.0):.2f},"
            f"{r.get('retries', 0)},{r.get('replica_fallbacks', 0)},"
            f"{r.get('degraded_reads', 0)},{r.get('repaired_pages', 0)},"
            f"{r.get('metadata_retries', 0)},{r.get('checksum_failures', 0)},"
            f"{r.get('lease_fences', 0)},{r.get('epoch_stalls', 0)}"
        )
    return out


def main(n_clients_list=(1, 2, 4, 8, 16), iters: int = 20,
         modes: Optional[Sequence[str]] = None) -> List[str]:
    return to_csv(run(n_clients_list=n_clients_list, iters=iters,
                      modes=tuple(modes) if modes else MODES))


if __name__ == "__main__":
    print("\n".join(main()))
