"""Paper Fig. 3(c): per-client bandwidth as concurrency grows.

20 provider nodes (data+metadata), 1 TB blob with 64 KB pages; N concurrent
clients each run a loop of reads (respectively writes) of disjoint segments
within a hot 1 GB window. The paper's claim: per-client bandwidth barely drops
as N grows (lock-free design, only the version-number interaction is
serialized). We measure aggregate and per-client wall-clock bandwidth for
reads, writes, and a mixed R/W workload.
"""

from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

from repro.configs.paper_sky import CONFIG as SKY
from repro.core import BlobStore


def run(n_clients_list=(1, 2, 4, 8, 16), seg_bytes=256 << 10, iters=20,
        page_size=64 << 10, n_providers=20) -> List[dict]:
    rows = []
    for mode in ("read", "write", "mixed"):
        for n_clients in n_clients_list:
            store = BlobStore(
                n_data_providers=n_providers, n_metadata_providers=n_providers,
                max_workers=4 * n_providers,
            )
            blob = store.alloc(SKY.blob_size, page_size)
            # pre-populate the hot window so reads hit real pages
            hot = SKY.hot_interval
            init = np.ones(seg_bytes, np.uint8)
            for off in range(0, min(hot, seg_bytes * n_clients * iters), seg_bytes):
                store.write(blob, init, off)

            barrier = threading.Barrier(n_clients)
            times: List[float] = [0.0] * n_clients

            def client(cid: int) -> None:
                rng = np.random.default_rng(cid)
                buf = np.full(seg_bytes, cid + 1, np.uint8)
                barrier.wait()
                t0 = time.perf_counter()
                for i in range(iters):
                    # disjoint segments per client (the paper's workload)
                    off = ((cid * iters + i) * seg_bytes) % hot
                    do_write = mode == "write" or (mode == "mixed" and i % 2 == 1)
                    if do_write:
                        store.write(blob, buf, off)
                    else:
                        store.read(blob, None, off, seg_bytes)
                times[cid] = time.perf_counter() - t0

            threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            per_client = [seg_bytes * iters / t / 1e6 for t in times]  # MB/s
            rows.append(dict(
                mode=mode, clients=n_clients,
                per_client_MBps=float(np.mean(per_client)),
                min_client_MBps=float(np.min(per_client)),
                aggregate_MBps=float(sum(per_client)),
            ))
            store.close()
    return rows


def main() -> List[str]:
    rows = run()
    out = ["mode,clients,per_client_MBps,min_client_MBps,aggregate_MBps"]
    for r in rows:
        out.append(
            f"{r['mode']},{r['clients']},{r['per_client_MBps']:.1f},"
            f"{r['min_client_MBps']:.1f},{r['aggregate_MBps']:.1f}"
        )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
