"""Blob-backed KV serving plane: the paged KV-cache hosted ON the
Cluster/Session blob store.

`storage/kvcache.py` keeps its bookkeeping in one process; this module puts
the same page pool on the versioned blob plane, which buys exactly the
paper's properties:

* **each KV page pool is a blob** — page *i* of the pool is the blob's page
  *i*, so a sequence's page table is a list of blob page indices ("slots");
* **a page table compiles to a readv plan** — :meth:`BlobKVClient.gather`
  groups a sequence's published pages by version and issues ONE vectored
  read per version group (usually one: a prompt publishes as one contiguous
  ``writev`` patch = one version), hitting the node's shared cache tier and
  deduplicating pages across concurrent sessions;
* **appended / COW-forked pages are writev/write_async patches** — each
  filled decode page is published as its own version, pipelined through the
  session's bounded async window;
* **published sequence versions are real VersionManager versions** — the
  host allocator's ad-hoc refcounts become snapshot pins
  (:meth:`Cluster.pin_published`), so GC, chaos and repair all see serving
  state as ordinary blob state;
* **the prefix index becomes cluster-wide** — full prompt pages are
  content-addressed (token chain hash, same function as the host allocator)
  into :class:`repro.core.page_directory.PageDirectory`, mapping hash →
  ``(blob_id, version, page)``. Any session of any user on the cluster that
  admits a prompt with the same prefix resolves the same triple and reads
  the bytes from the shared cache tier: N sessions share a system prompt
  with zero recompute and zero duplicate storage.

Coherence is the publish-frontier invariant, not invalidation: only
*published* versions can enter the directory (``pin_published`` validates
the frontier before the entry becomes visible) and only published versions
can be read through ``Session.read_pages`` — so a cross-session read of an
unpublished KV page is impossible by construction. Published pages are
immutable, so cache entries never need invalidating.

Locking: ``BlobKVStore._lock`` (level 3) guards the slot free-list and
refcounts only. Directory calls (which pin under the level-1 GC guard) are
always made with the store lock RELEASED; the directory's eviction hook
re-enters the store lock from outside the directory lock. ``BlobKVClient``
and :class:`KVSeq` are single-threaded per engine (like the host
allocator); the shared state is the store + directory + cluster.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.cluster import Cluster, Session
from repro.core.page_directory import PageAddress
from repro.storage.kvcache import chain_hash


# ------------------------------ page packing ------------------------------
def kv_page_nbytes(
    n_layers: int, page_tokens: int, n_kv_heads: int, head_dim: int, dtype
) -> int:
    """Payload bytes of one packed KV page: K and V for all layers of
    ``page_tokens`` positions."""
    return 2 * n_layers * page_tokens * n_kv_heads * head_dim * np.dtype(dtype).itemsize


def pack_kv_page(pk_page, pv_page, page_size: int) -> np.ndarray:
    """Flatten one page's K and V (shape ``(L, T, K, hd)`` each) into a
    zero-padded ``page_size``-byte buffer for the blob write plane."""
    k = np.ascontiguousarray(np.asarray(pk_page)).reshape(-1)
    v = np.ascontiguousarray(np.asarray(pv_page)).reshape(-1)
    raw = np.concatenate([k, v]).view(np.uint8)
    if raw.size > page_size:
        raise ValueError(
            f"KV page payload ({raw.size}B) exceeds blob page ({page_size}B)"
        )
    buf = np.zeros(page_size, np.uint8)
    buf[: raw.size] = raw
    return buf


def unpack_kv_page(
    buf: np.ndarray, shape: Tuple[int, int, int, int], dtype
) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`pack_kv_page`; ``shape`` is ``(L, T, K, hd)``."""
    count = int(np.prod(shape))
    nbytes = count * np.dtype(dtype).itemsize
    flat = np.ascontiguousarray(buf[: 2 * nbytes]).view(dtype)
    return flat[:count].reshape(shape), flat[count:].reshape(shape)


# --------------------------------- store ----------------------------------
class BlobKVStore:
    """One KV page pool hosted as one blob, shared by every client on the
    cluster. Owns the *slot* (blob page index) space: a free list plus
    refcounts, where the cluster's :class:`PageDirectory` holds a reference
    for every prefix entry it advertises and each sequence holds references
    for the slots it uses — a slot returns to the free list only when the
    last reference drops, so a republished slot can never clobber a page
    someone still addresses *at an older version* (old versions stay
    readable regardless: blob writes are COW)."""

    def __init__(
        self,
        cluster: Cluster,
        n_pages: int,
        page_bytes: int,
        page_tokens: int,
        kv_shape: Optional[Tuple[int, int, int, int]] = None,
        kv_dtype=None,
    ) -> None:
        if n_pages <= 0 or page_bytes <= 0:
            raise ValueError("n_pages and page_bytes must be positive")
        self.cluster = cluster
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        #: blob pages are power-of-two sized; the KV payload is zero-padded
        self.page_size = 1 << (max(page_bytes, 1) - 1).bit_length()
        self.kv_shape = kv_shape
        self.kv_dtype = kv_dtype
        self.blob_id = cluster.alloc(n_pages * self.page_size, self.page_size)
        self.directory = cluster.page_directory
        self.directory.add_evict_hook(self._on_directory_evict)
        self._lock = make_lock("BlobKVStore._lock")
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        #: directory key -> slot the index's reference is parked on
        self._key_slot: Dict[int, int] = {}
        self.stats = {
            "slot_alloc": 0, "slot_freed": 0, "prefix_hits": 0,
            "prefix_misses": 0, "prefix_registered": 0, "evictions": 0,
        }

    @classmethod
    def for_kv(
        cls,
        cluster: Cluster,
        n_pages: int,
        page_tokens: int,
        n_layers: int,
        n_kv_heads: int,
        head_dim: int,
        dtype,
    ) -> "BlobKVStore":
        """Size the pool for a model's KV geometry (one slot holds K+V for
        all layers of one page of positions)."""
        return cls(
            cluster,
            n_pages,
            kv_page_nbytes(n_layers, page_tokens, n_kv_heads, head_dim, dtype),
            page_tokens,
            kv_shape=(n_layers, page_tokens, n_kv_heads, head_dim),
            kv_dtype=np.dtype(dtype),
        )

    # -- slot space ---------------------------------------------------------
    @property
    def free_slots(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def used_slots(self) -> int:
        return self.n_pages - self.free_slots

    def alloc_slots(self, n: int) -> List[int]:
        """Allocate ``n`` slots (each ref=1, owned by the caller). Under
        pressure, reclaims one directory-advertised slot of this pool per
        retry — the cluster-wide analogue of the host allocator's
        prefix-cache eviction — and raises ``MemoryError`` once the
        directory holds nothing evictable (everything pinned by live
        sequences)."""
        got: List[int] = []
        while True:
            with self._lock:
                while self._free and len(got) < n:
                    slot = self._free.pop()
                    self._ref[slot] = 1
                    got.append(slot)
                if len(got) == n:
                    self.stats["slot_alloc"] += n
                    return got
            # pool dry: ask the directory to drop an unreferenced prefix
            # entry of THIS blob (its evict hook frees the slot). Called with
            # the store lock released — the hook re-enters it.
            if not self.directory.evict_unreferenced(1, blob_id=self.blob_id):
                with self._lock:
                    for slot in got:
                        self._release_locked(slot)
                raise MemoryError("blob KV pool exhausted")
            self.stats["evictions"] += 1

    def retain_slot(self, slot: int) -> None:
        with self._lock:
            self._ref[slot] += 1

    def release_slot(self, slot: int) -> None:
        with self._lock:
            self._release_locked(slot)

    def _release_locked(self, slot: int) -> None:
        self._ref[slot] -= 1
        if self._ref[slot] == 0:
            del self._ref[slot]
            self._free.append(slot)
            self.stats["slot_freed"] += 1

    # -- cluster-wide prefix index -------------------------------------------
    def register_prefix(self, key: int, slot: int, version: int) -> PageAddress:
        """Advertise ``key`` → this pool's ``slot`` at ``version`` in the
        cluster directory. The index parks a slot reference (dropped by the
        eviction hook); on a registration race the first publisher wins and
        our reference is returned. The directory validates+pins the version
        — registering an unpublished page raises."""
        with self._lock:
            self._ref[slot] += 1
            self._key_slot[key] = slot
        try:
            winner = self.directory.publish(key, self.blob_id, version, slot)
        except Exception:
            with self._lock:
                if self._key_slot.get(key) == slot:
                    del self._key_slot[key]
                self._release_locked(slot)
            raise
        if winner.page != slot or winner.version != version:
            with self._lock:
                if self._key_slot.get(key) == slot:
                    del self._key_slot[key]
                self._release_locked(slot)
        else:
            self.stats["prefix_registered"] += 1
        return winner

    def lookup_prefix(self, key: int) -> Optional[PageAddress]:
        """Resolve a prefix page: takes a directory entry refcount (blocks
        eviction) AND a slot reference for the caller; both are returned by
        :meth:`release_prefix`."""
        addr = self.directory.acquire(key)
        if addr is None:
            self.stats["prefix_misses"] += 1
            return None
        if addr.blob_id != self.blob_id:
            self.directory.release(key)
            self.stats["prefix_misses"] += 1
            return None
        self.retain_slot(addr.page)
        self.stats["prefix_hits"] += 1
        return addr

    def release_prefix(self, key: int, addr: PageAddress) -> None:
        self.release_slot(addr.page)
        self.directory.release(key)

    def _on_directory_evict(self, key: int, address: PageAddress) -> None:
        if address.blob_id != self.blob_id:
            return
        with self._lock:
            slot = self._key_slot.pop(key, None)
            if slot is not None:
                self._release_locked(slot)


# -------------------------------- sequences --------------------------------
@dataclasses.dataclass
class KVSeq:
    """One sequence's view of the pool: slot table plus, per page, the
    published address (``None`` while the page is local-only — device
    resident, not yet a blob version — which is exactly the set of pages no
    other session can see)."""

    seq_id: int
    length: int  # tokens accounted so far
    slots: List[int]  # blob page indices, positional
    shared_tokens: int  # first shared_tokens came from the cluster directory
    page_addr: List[Optional[PageAddress]]  # publish address per page
    hashes: List[Optional[int]]  # chain hash per FULL prompt page
    shared: List[Tuple[int, PageAddress]]  # (directory key, addr) we hold
    owned: List[int] = dataclasses.field(default_factory=list)  # slots to free
    pinned_versions: List[int] = dataclasses.field(default_factory=list)
    pending: List[Tuple[int, int, object]] = dataclasses.field(
        default_factory=list
    )  # (page_index, slot, Future[version]) of in-flight publishes

    @property
    def n_shared_pages(self) -> int:
        return len(self.shared)


class BlobKVClient:
    """Per-engine façade: the :class:`PagedKVAllocator` lifecycle
    (admit/append/finish/table) re-expressed as blob operations through ONE
    session. Not thread-safe (one client per engine loop, like the host
    allocator); any number of clients share one :class:`BlobKVStore`."""

    def __init__(
        self,
        store: BlobKVStore,
        session: Optional[Session] = None,
        use_prefix_cache: bool = True,
    ) -> None:
        self.store = store
        self.session = session if session is not None else store.cluster.session()
        self.handle = self.session.open(store.blob_id)
        #: opt out of the cluster-wide prefix directory (benchmark A/B: a
        #: client that neither shares nor advertises prompt pages)
        self.use_prefix_cache = use_prefix_cache
        self._seqs: Dict[int, KVSeq] = {}
        self._next_seq = 0
        self.stats = {"admitted": 0, "shared_tokens": 0, "published_pages": 0,
                      "gathers": 0, "gather_reads": 0}

    # -- lifecycle -----------------------------------------------------------
    def admit(self, tokens: Sequence[int]) -> Tuple[KVSeq, int, List[Tuple[int, PageAddress]]]:
        """Admit a prompt. Returns ``(seq, n_shared_tokens, fetches)`` where
        ``fetches`` lists the shared pages as ``(page_index, PageAddress)``
        — the engine reads any it doesn't hold device-resident via
        :meth:`fetch_pages` (shared cache tier → usually free). Only FULL
        prompt pages are shared cluster-wide; the partial tail page is
        always fresh (cross-user COW of a mutable head has no meaning on an
        immutable blob). Raises ``MemoryError`` (with all acquisitions
        rolled back) when the pool is exhausted."""
        tokens = tuple(int(t) for t in tokens)
        T = self.store.page_tokens
        slots: List[int] = []
        page_addr: List[Optional[PageAddress]] = []
        hashes: List[Optional[int]] = []
        shared: List[Tuple[int, PageAddress]] = []
        h = 0
        while self.use_prefix_cache and (len(shared) + 1) * T <= len(tokens):
            h2 = chain_hash(h, tokens[len(shared) * T : (len(shared) + 1) * T])
            addr = self.store.lookup_prefix(h2)
            if addr is None:
                break
            slots.append(addr.page)
            page_addr.append(addr)
            hashes.append(h2)
            shared.append((h2, addr))
            h = h2
        n_shared = len(shared) * T

        # chain hashes of the remaining FULL pages (fresh, publishable)
        n_full = len(tokens) // T
        for i in range(len(shared), n_full):
            h = chain_hash(h, tokens[i * T : (i + 1) * T])
            hashes.append(h)
        rest = len(tokens) - n_shared
        n_fresh = (rest + T - 1) // T
        if len(tokens) % T:
            hashes.append(None)  # the partial tail page has no full-page hash
        try:
            fresh = self.store.alloc_slots(n_fresh)
        except MemoryError:
            for key, addr in shared:
                self.store.release_prefix(key, addr)
            raise
        slots.extend(fresh)
        page_addr.extend([None] * n_fresh)

        seq = KVSeq(
            self._next_seq, len(tokens), slots, n_shared, page_addr, hashes,
            shared, owned=list(fresh),
        )
        self._next_seq += 1
        self._seqs[seq.seq_id] = seq
        self.stats["admitted"] += 1
        self.stats["shared_tokens"] += n_shared
        return seq, n_shared, list(enumerate(page_addr[: len(shared)]))

    def fork_for_batch(self, seq: KVSeq, busy) -> List[Tuple[int, int]]:
        """Fork any slot of ``seq`` that another live row of the same decode
        batch already schedules (``busy``): the owner-indexed attention kernel
        gives each pool page exactly one owner row per batch, so concurrent
        rows must be page-disjoint. The fork is a device copy into a fresh
        slot — the shared bytes were already fetched, nothing is recomputed
        and the directory entry (still advertising the donor's published page)
        is untouched; this sequence's directory refs are dropped at
        ``finish`` as usual. Returns (src, dst) device copies; on
        ``MemoryError`` the sequence stays consistent (roll back via
        :meth:`finish`)."""
        copies: List[Tuple[int, int]] = []
        for i, slot in enumerate(seq.slots):
            if slot not in busy:
                continue
            fresh = self.store.alloc_slots(1)[0]
            copies.append((slot, fresh))
            seq.slots[i] = fresh
            seq.owned.append(fresh)
            seq.page_addr[i] = None  # local-only: never republished
        return copies

    def append_token(self, seq: KVSeq) -> Optional[int]:
        """Account one decoded token; returns a freshly allocated slot when
        the head page grew (the engine writes device-side only — blob
        publication happens per *filled* page via
        :meth:`publish_page_async`)."""
        head = seq.length // self.store.page_tokens
        grown: Optional[int] = None
        if head >= len(seq.slots):
            grown = self.store.alloc_slots(1)[0]
            seq.slots.append(grown)
            seq.owned.append(grown)
            seq.page_addr.append(None)
            seq.hashes.append(None)
        else:
            # writing into a published page would desynchronize the device
            # copy from the immutable blob bytes — the table construction
            # above guarantees the head is always a fresh local page
            assert seq.page_addr[head] is None, "decode write into published page"
        seq.length += 1
        return grown

    def finish(self, seq: KVSeq) -> None:
        """Drain publishes, drop every pin/reference this sequence holds.
        Published pages remain readable by anyone who pinned them (directory
        entries, other sequences' snapshots) — exactly the paper's 'old
        versions stay readable'."""
        self.drain_publishes(seq)
        for version in seq.pinned_versions:
            self.store.cluster.unpin_version(self.store.blob_id, version)
        seq.pinned_versions.clear()
        for key, addr in seq.shared:
            self.store.release_prefix(key, addr)
        for slot in seq.owned:
            self.store.release_slot(slot)
        seq.shared = []
        seq.owned = []
        seq.slots = []
        self._seqs.pop(seq.seq_id, None)

    def table(self, seq: KVSeq, max_pages: int) -> List[int]:
        """Device page-table row, padded with the out-of-bounds sentinel."""
        pad = [self.store.n_pages] * (max_pages - len(seq.slots))
        return list(seq.slots) + pad

    # -- publish (scatter) ---------------------------------------------------
    def publish_prompt(self, seq: KVSeq, payloads: Dict[int, np.ndarray]) -> List[int]:
        """Publish the fresh FULL prompt pages (``payloads``: page index →
        packed page buffer) as ONE ``writev``: contiguous slot runs coalesce
        into single patches, so the whole prompt usually publishes as one
        version — which is what lets :meth:`gather` compile the page table
        into a single readv plan. Each page is then content-registered in
        the cluster directory."""
        if not payloads:
            return []
        items = sorted(payloads.items())
        page_size = self.store.page_size
        runs: List[List[Tuple[int, np.ndarray]]] = [[items[0]]]
        for idx, buf in items[1:]:
            last_idx, _ = runs[-1][-1]
            if idx == last_idx + 1 and seq.slots[idx] == seq.slots[last_idx] + 1:
                runs[-1].append((idx, buf))
            else:
                runs.append([(idx, buf)])
        patches = [
            (
                seq.slots[run[0][0]] * page_size,
                np.concatenate([np.asarray(buf, np.uint8) for _, buf in run]),
            )
            for run in runs
        ]
        versions = self.handle.writev(patches)
        for run, version in zip(runs, versions):
            # writev success means durable; publication is IN-ORDER behind
            # concurrent writers' versions — wait for the frontier to reach
            # us, then pin (the paper's ordered publication, per §IV)
            self.handle.wait_for_version(version)
            self.store.cluster.pin_published(self.store.blob_id, version)
            seq.pinned_versions.append(version)
            for idx, _ in run:
                addr = PageAddress(self.store.blob_id, version, seq.slots[idx])
                seq.page_addr[idx] = addr
                self.stats["published_pages"] += 1
                if self.use_prefix_cache and seq.hashes[idx] is not None:
                    self.store.register_prefix(
                        seq.hashes[idx], seq.slots[idx], version
                    )
        return versions

    def publish_page_async(self, seq: KVSeq, page_index: int, payload: np.ndarray) -> None:
        """Queue one filled decode page into the session's bounded async
        write window (the paper's overlapped write pipeline); resolved by
        :meth:`drain_publishes`."""
        slot = seq.slots[page_index]
        fut = self.handle.write_async(
            np.asarray(payload, np.uint8), slot * self.store.page_size
        )
        seq.pending.append((page_index, slot, fut))

    def drain_publishes(self, seq: KVSeq) -> None:
        pending, seq.pending = seq.pending, []
        for page_index, slot, fut in pending:
            version = fut.result()
            self.handle.wait_for_version(version)  # in-order publication
            self.store.cluster.pin_published(self.store.blob_id, version)
            seq.pinned_versions.append(version)
            seq.page_addr[page_index] = PageAddress(
                self.store.blob_id, version, slot
            )
            self.stats["published_pages"] += 1

    def pending_pages(self, seq: KVSeq) -> List[int]:
        return [idx for idx, _, _ in seq.pending]

    # -- gather (the readv plan) ---------------------------------------------
    def gather(
        self, seq: KVSeq, page_indices: Optional[Sequence[int]] = None
    ) -> List[Tuple[int, np.ndarray]]:
        """Compile the sequence's page table into a readv plan and execute
        it: published pages grouped by version, ONE vectored page read per
        group (full-page segments are zero-copy views of cached pages).
        Local-only (unpublished) pages are skipped — they exist solely in
        the owning engine's device pool, which is why no other session can
        ever observe them. Returns ``(page_index, bytes)`` pairs."""
        idxs = range(len(seq.slots)) if page_indices is None else page_indices
        plan: Dict[int, List[Tuple[int, int]]] = {}
        for i in idxs:
            addr = seq.page_addr[i]
            if addr is None:
                continue
            plan.setdefault(addr.version, []).append((i, addr.page))
        out: List[Tuple[int, np.ndarray]] = []
        self.stats["gathers"] += 1
        for version in sorted(plan):
            group = plan[version]
            data = self.session.read_pages(
                self.store.blob_id, version, [s for _, s in group], pinned=True
            )
            self.stats["gather_reads"] += 1
            out.extend((i, buf) for (i, _), buf in zip(group, data))
        return out

    def fetch_pages(self, addrs: Sequence[PageAddress]) -> List[np.ndarray]:
        """Read arbitrary published page addresses (grouped by version, one
        vectored read per group), preserving input order — the admit-time
        fetch of shared prefix pages into a device pool."""
        plan: Dict[int, List[Tuple[int, int]]] = {}
        for i, addr in enumerate(addrs):
            plan.setdefault(addr.version, []).append((i, addr.page))
        out: List[Optional[np.ndarray]] = [None] * len(addrs)
        for version, group in plan.items():
            data = self.session.read_pages(
                self.store.blob_id, version, [p for _, p in group], pinned=True
            )
            for (i, _), buf in zip(group, data):
                out[i] = buf
        return out  # type: ignore[return-value]
