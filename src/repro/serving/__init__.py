from repro.serving.engine import Completion, Request, ServingEngine

__all__ = ["Completion", "Request", "ServingEngine"]
