from repro.serving.blob_kv import (
    BlobKVClient,
    BlobKVStore,
    KVSeq,
    kv_page_nbytes,
    pack_kv_page,
    unpack_kv_page,
)
from repro.serving.engine import Completion, Request, ServingEngine

__all__ = [
    "BlobKVClient",
    "BlobKVStore",
    "Completion",
    "KVSeq",
    "Request",
    "ServingEngine",
    "kv_page_nbytes",
    "pack_kv_page",
    "unpack_kv_page",
]
