"""Continuous-batching serving engine on the paged, versioned KV store.

The engine is the paper's client+provider-manager loop applied to inference:

* requests are admitted when a batch slot AND pool pages are available
  (provider-manager placement via :class:`PagedKVAllocator`);
* prompt prefixes matching cached pages are SHARED (COW snapshots — no
  recompute, no extra storage);
* decode steps read striped pages concurrently (lock-free R/R), append fresh
  pages (W/W on disjoint pages), and COW-fork any page a snapshot still pins;
* a request's output is a *published version* of its sequence — earlier
  snapshots remain readable for as long as a reader holds them.

Single-host reference implementation: device arrays live on the default
device (or a mesh via ``axis_info``); the same step functions are what
``launch/serve.py`` shards.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import Model, build_model
from repro.storage.kvcache import PagedKVAllocator


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prefill_skipped_tokens: int  # prefix-cache savings
    latency_s: float


class ServingEngine:
    """Greedy/temperature sampling, fixed slot count, paged pool."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_slots: int = 4,
        n_pages: int = 256,
        max_pages_per_seq: int = 32,
        rng_seed: int = 0,
    ) -> None:
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.T = cfg.kv_page_tokens
        self.max_slots = max_slots
        self.Rmax = max_pages_per_seq
        self.alloc = PagedKVAllocator(n_pages, self.T)
        self._rng = np.random.default_rng(rng_seed)

        L = self._n_attn_layers()
        K, hd = cfg.n_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.kv_cache_dtype)
        if dt == jnp.int8:
            # the engine scatters raw prefill pages; int8 pools (decode-path
            # quantization) would need a quantizing scatter here — keep bf16
            dt = jnp.dtype(jnp.bfloat16)
        self.pool_k = jnp.zeros((L, n_pages, self.T, K, hd), dt)
        self.pool_v = jnp.zeros((L, n_pages, self.T, K, hd), dt)
        self._slots: List[Optional[dict]] = [None] * max_slots
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._done: Dict[int, Completion] = {}

        self._jit_prefill_tokens = jax.jit(self._prefill_tokens_impl)
        self._jit_decode = jax.jit(self._decode_impl)
        self._jit_copy_pages = jax.jit(self._copy_pages_impl)

    def _n_attn_layers(self) -> int:
        cfg = self.cfg
        if cfg.family == "hybrid":
            return cfg.n_layers // cfg.attn_every
        if cfg.family in ("encdec", "audio"):
            return cfg.n_dec_layers
        return cfg.n_layers

    # ------------------------- jitted step functions -------------------------
    def _prefill_tokens_impl(self, params, tokens):
        """Prefill one request (padded to a page multiple); returns last-token
        logits + per-layer paged K/V of the prompt."""
        logits, cache = self.model.prefill(params, {"tokens": tokens}, None)
        kv = cache["kv"] if "kv" in cache else cache["self_kv"]
        return logits, kv["pool_k"], kv["pool_v"]

    def _decode_impl(self, params, pool_k, pool_v, tables, page_pos, lengths, tokens):
        L = pool_k.shape[0]
        cache = {
            "kv": {
                "pool_k": pool_k,
                "pool_v": pool_v,
                # all layers share one table (the pools are stacked per layer)
                "tables": jnp.broadcast_to(tables, (L,) + tables.shape),
                "page_pos": jnp.broadcast_to(page_pos, (L,) + page_pos.shape),
            },
            "lengths": lengths,
        }
        logits, new_cache = self.model.decode_step(params, cache, tokens, None)
        kv = new_cache["kv"]
        return logits, kv["pool_k"], kv["pool_v"], kv["page_pos"]

    def _copy_pages_impl(self, pool_k, pool_v, src, dst):
        return pool_k.at[:, dst].set(pool_k[:, src]), pool_v.at[:, dst].set(pool_v[:, src])

    # ------------------------------ lifecycle ------------------------------
    def submit(self, req: Request) -> None:
        self._queue.put(req)

    def _admit(self) -> None:
        while not self._queue.empty() and None in self._slots:
            req = self._queue.get()
            prompt = list(req.prompt)
            pad = (-len(prompt)) % self.T
            padded = prompt + [0] * pad
            need_pages = len(padded) // self.T + 1
            if self.alloc.free_pages < need_pages:
                # not enough pages: requeue and stop admitting (backpressure)
                self._queue.put(req)
                return
            seq, shared_tokens, _ = self.alloc.admit(prompt)
            slot = self._slots.index(None)

            # prefill (full recompute of non-shared part; prefix-shared pages
            # need no recompute but we still need last-token logits, so run
            # the model over the whole prompt — the page WRITES are skipped
            # for shared pages)
            toks = jnp.asarray(padded, jnp.int32)[None]
            logits, pk, pv = self._jit_prefill_tokens(self.params, toks)
            n_prompt_pages = len(padded) // self.T
            # scatter non-shared prompt pages into the big pool at their ids
            first_new = shared_tokens // self.T
            for p in range(first_new, n_prompt_pages):
                pid = seq.pages[p]
                self.pool_k = self.pool_k.at[:, pid].set(pk[:, p])
                self.pool_v = self.pool_v.at[:, pid].set(pv[:, p])

            next_tok = self._sample(np.asarray(logits)[0], req.temperature)
            self._slots[slot] = dict(
                req=req, seq=seq, generated=[int(next_tok)], t0=time.time(),
                shared=shared_tokens, length=len(prompt),
            )

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        logits = logits[: self.cfg.vocab_size]
        if temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / temperature)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> int:
        """One engine iteration: admit + one batched decode step. Returns the
        number of active sequences."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0

        # COW-fork / grow head pages before writing this step's token
        copies: List[Tuple[int, int]] = []
        for i in active:
            st = self._slots[i]
            copies.extend(self.alloc.append_token(st["seq"].seq_id))
        if copies:
            src = jnp.asarray([c[0] for c in copies], jnp.int32)
            dst = jnp.asarray([c[1] for c in copies], jnp.int32)
            self.pool_k, self.pool_v = self._jit_copy_pages(self.pool_k, self.pool_v, src, dst)

        B = self.max_slots
        # inactive rows keep the OOB sentinel so they own no pages
        tables = np.full((B, self.Rmax), self.alloc.n_pages, np.int32)
        page_pos = np.zeros((B, self.Rmax), np.int32)
        lengths = np.zeros((B,), np.int32)
        tokens = np.zeros((B,), np.int32)
        for i in active:
            st = self._slots[i]
            row = self.alloc.table(st["seq"].seq_id, self.Rmax)
            tables[i] = row
            page_pos[i] = np.arange(self.Rmax) * self.T  # positional pages (no ring)
            lengths[i] = st["length"] + len(st["generated"]) - 1
            tokens[i] = st["generated"][-1]

        logits, self.pool_k, self.pool_v, _ = self._jit_decode(
            self.params, self.pool_k, self.pool_v,
            jnp.asarray(tables), jnp.asarray(page_pos), jnp.asarray(lengths),
            jnp.asarray(tokens),
        )
        logits = np.asarray(logits)

        for i in active:
            st = self._slots[i]
            tok = self._sample(logits[i], st["req"].temperature)
            st["generated"].append(tok)
            if len(st["generated"]) >= st["req"].max_new_tokens:
                self._finish(i)
        return len(active)

    def _finish(self, slot: int) -> None:
        st = self._slots[slot]
        self.alloc.finish(st["seq"].seq_id)
        self._done[st["req"].request_id] = Completion(
            st["req"].request_id,
            st["generated"],
            st["shared"],
            time.time() - st["t0"],
        )
        self._slots[slot] = None

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Completion]:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and self._queue.empty():
                break
        return dict(self._done)
