"""Continuous-batching serving engine on the paged, versioned KV store.

The engine is the paper's client+provider-manager loop applied to inference:

* requests are admitted when a batch slot AND pool pages are available
  (provider-manager placement via :class:`PagedKVAllocator`);
* prompt prefixes matching cached pages are SHARED (COW snapshots — no
  recompute, no extra storage);
* decode steps read striped pages concurrently (lock-free R/R), append fresh
  pages (W/W on disjoint pages), and COW-fork any page a snapshot still pins;
* a request's output is a *published version* of its sequence — earlier
  snapshots remain readable for as long as a reader holds them.

Single-host reference implementation: device arrays live on the default
device (or a mesh via ``axis_info``); the same step functions are what
``launch/serve.py`` shards.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import Model, build_model
from repro.serving.blob_kv import BlobKVClient, pack_kv_page, unpack_kv_page
from repro.storage.kvcache import PagedKVAllocator


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prefill_skipped_tokens: int  # prefix-cache savings
    latency_s: float


class ServingEngine:
    """Greedy/temperature sampling, fixed slot count, paged pool."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_slots: int = 4,
        n_pages: int = 256,
        max_pages_per_seq: int = 32,
        rng_seed: int = 0,
        kv_client: Optional[BlobKVClient] = None,
    ) -> None:
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.T = cfg.kv_page_tokens
        self.max_slots = max_slots
        self.Rmax = max_pages_per_seq
        #: blob mode: the page pool is a blob on a Cluster and the prefix
        #: index is the cluster-wide PageDirectory — slot ids come from the
        #: shared BlobKVStore, so the device pool mirrors the blob geometry
        self.kv = kv_client
        if kv_client is not None:
            if kv_client.store.page_tokens != self.T:
                raise ValueError(
                    "BlobKVStore page_tokens != model kv_page_tokens"
                )
            n_pages = kv_client.store.n_pages
            self.alloc = None
            #: slot -> published version currently resident in the device
            #: pool (a stale entry just causes a refetch: versions are
            #: monotone, so a reused slot republishes at a higher version)
            self._resident: Dict[int, int] = {}
        else:
            self.alloc = PagedKVAllocator(n_pages, self.T)
        self._rng = np.random.default_rng(rng_seed)

        L = self._n_attn_layers()
        K, hd = cfg.n_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.kv_cache_dtype)
        if dt == jnp.int8:
            # the engine scatters raw prefill pages; int8 pools (decode-path
            # quantization) would need a quantizing scatter here — keep bf16
            dt = jnp.dtype(jnp.bfloat16)
        self.n_pool_pages = n_pages
        self.pool_k = jnp.zeros((L, n_pages, self.T, K, hd), dt)
        self.pool_v = jnp.zeros((L, n_pages, self.T, K, hd), dt)
        self._slots: List[Optional[dict]] = [None] * max_slots
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._done: Dict[int, Completion] = {}

        self._jit_prefill_tokens = jax.jit(self._prefill_tokens_impl)
        self._jit_decode = jax.jit(self._decode_impl)
        self._jit_copy_pages = jax.jit(self._copy_pages_impl)

    def _n_attn_layers(self) -> int:
        cfg = self.cfg
        if cfg.family == "hybrid":
            return cfg.n_layers // cfg.attn_every
        if cfg.family in ("encdec", "audio"):
            return cfg.n_dec_layers
        return cfg.n_layers

    # ------------------------- jitted step functions -------------------------
    def _prefill_tokens_impl(self, params, tokens):
        """Prefill one request (padded to a page multiple); returns last-token
        logits + per-layer paged K/V of the prompt."""
        logits, cache = self.model.prefill(params, {"tokens": tokens}, None)
        kv = cache["kv"] if "kv" in cache else cache["self_kv"]
        return logits, kv["pool_k"], kv["pool_v"]

    def _decode_impl(self, params, pool_k, pool_v, tables, page_pos, lengths, tokens):
        L = pool_k.shape[0]
        cache = {
            "kv": {
                "pool_k": pool_k,
                "pool_v": pool_v,
                # all layers share one table (the pools are stacked per layer)
                "tables": jnp.broadcast_to(tables, (L,) + tables.shape),
                "page_pos": jnp.broadcast_to(page_pos, (L,) + page_pos.shape),
            },
            "lengths": lengths,
        }
        logits, new_cache = self.model.decode_step(params, cache, tokens, None)
        kv = new_cache["kv"]
        return logits, kv["pool_k"], kv["pool_v"], kv["page_pos"]

    def _copy_pages_impl(self, pool_k, pool_v, src, dst):
        return pool_k.at[:, dst].set(pool_k[:, src]), pool_v.at[:, dst].set(pool_v[:, src])

    # ------------------------------ lifecycle ------------------------------
    def submit(self, req: Request) -> None:
        self._queue.put(req)

    def _admit(self) -> None:
        while not self._queue.empty() and None in self._slots:
            req = self._queue.get()
            prompt = list(req.prompt)
            pad = (-len(prompt)) % self.T
            padded = prompt + [0] * pad
            need_pages = len(padded) // self.T + 1
            # pages every live row already schedules: the owner-indexed
            # attention kernel (kernels/ops.py page_ownership) gives each pool
            # page exactly ONE owner row per batch, so a new row sharing a
            # page with a live row must COW-fork it on device — prefix
            # sharing is storage-level across time, never within a batch
            busy = set()
            for s in self._slots:
                if s is not None:
                    busy.update(
                        s["seq"].pages if self.kv is None else s["seq"].slots
                    )
            if self.kv is None:
                if self.alloc.free_pages < need_pages:
                    # not enough pages: requeue, stop admitting (backpressure)
                    self._queue.put(req)
                    return
                seq, shared_tokens, cow = self.alloc.admit(prompt)
                try:
                    cow = cow + self.alloc.fork_for_batch(seq.seq_id, busy)
                except MemoryError:
                    self.alloc.finish(seq.seq_id)
                    self._queue.put(req)
                    return
                pages = seq.pages
                if cow:
                    # partial-page prefix reuse + batch-conflict forks: copy
                    # donor pages on device before anything writes the pool
                    src = jnp.asarray([c[0] for c in cow], jnp.int32)
                    dst = jnp.asarray([c[1] for c in cow], jnp.int32)
                    self.pool_k, self.pool_v = self._jit_copy_pages(
                        self.pool_k, self.pool_v, src, dst
                    )
            else:
                try:
                    seq, shared_tokens, fetches = self.kv.admit(prompt)
                except MemoryError:
                    # blob pool exhausted (directory had nothing evictable):
                    # same backpressure as the host allocator path
                    self._queue.put(req)
                    return
                pages = seq.slots
                # make shared prefix pages device-resident (one vectored
                # read through the shared cache tier per version group)
                self._load_shared_pages(fetches)
                try:
                    forks = self.kv.fork_for_batch(seq, busy)
                except MemoryError:
                    self.kv.finish(seq)
                    self._queue.put(req)
                    return
                if forks:
                    src = jnp.asarray([c[0] for c in forks], jnp.int32)
                    dst = jnp.asarray([c[1] for c in forks], jnp.int32)
                    self.pool_k, self.pool_v = self._jit_copy_pages(
                        self.pool_k, self.pool_v, src, dst
                    )
                    for _, d in forks:
                        # forked bytes are local-only: no published version
                        # is resident in that slot anymore
                        self._resident.pop(d, None)
            slot = self._slots.index(None)

            # prefill (full recompute of non-shared part; prefix-shared pages
            # need no recompute but we still need last-token logits, so run
            # the model over the whole prompt — the page WRITES are skipped
            # for shared pages)
            toks = jnp.asarray(padded, jnp.int32)[None]
            logits, pk, pv = self._jit_prefill_tokens(self.params, toks)
            n_prompt_pages = len(padded) // self.T
            # scatter non-shared prompt pages into the big pool at their ids;
            # ceil: a partially-shared (COW-forked) final page already holds
            # every prompt token this request needs
            first_new = -(-shared_tokens // self.T)
            for p in range(first_new, n_prompt_pages):
                pid = pages[p]
                self.pool_k = self.pool_k.at[:, pid].set(pk[:, p])
                self.pool_v = self.pool_v.at[:, pid].set(pv[:, p])
                if self.kv is not None:
                    self._resident.pop(pid, None)  # local bytes now newer
            if self.kv is not None:
                # publish the fresh FULL prompt pages as one writev (one
                # version) and register them in the cluster prefix directory
                full_pages = len(prompt) // self.T
                payloads = {
                    p: pack_kv_page(pk[:, p], pv[:, p], self.kv.store.page_size)
                    for p in range(first_new, full_pages)
                }
                self.kv.publish_prompt(seq, payloads)
                for p in range(first_new, full_pages):
                    addr = seq.page_addr[p]
                    self._resident[addr.page] = addr.version

            next_tok = self._sample(np.asarray(logits)[0], req.temperature)
            self._slots[slot] = dict(
                req=req, seq=seq, generated=[int(next_tok)], t0=time.time(),
                shared=shared_tokens, length=len(prompt),
            )

    def _load_shared_pages(self, fetches) -> None:
        """Fetch shared prefix pages this device pool doesn't hold at their
        published version and scatter them in (admit-time gather)."""
        stale = [
            (i, a) for i, a in fetches
            if self._resident.get(a.page) != a.version
        ]
        if not stale:
            return
        L, _, _, K, hd = self.pool_k.shape
        shape = (L, self.T, K, hd)
        dt = np.dtype(self.pool_k.dtype)
        bufs = self.kv.fetch_pages([a for _, a in stale])
        for (_, addr), buf in zip(stale, bufs):
            k, v = unpack_kv_page(np.asarray(buf), shape, dt)
            self.pool_k = self.pool_k.at[:, addr.page].set(jnp.asarray(k))
            self.pool_v = self.pool_v.at[:, addr.page].set(jnp.asarray(v))
            self._resident[addr.page] = addr.version

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        logits = logits[: self.cfg.vocab_size]
        if temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / temperature)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> int:
        """One engine iteration: admit + one batched decode step. Returns the
        number of active sequences."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0

        # COW-fork / grow head pages before writing this step's token
        copies: List[Tuple[int, int]] = []
        for i in active:
            st = self._slots[i]
            if self.kv is not None:
                self.kv.append_token(st["seq"])  # head always fresh: no COW
            else:
                copies.extend(self.alloc.append_token(st["seq"].seq_id))
        if copies:
            src = jnp.asarray([c[0] for c in copies], jnp.int32)
            dst = jnp.asarray([c[1] for c in copies], jnp.int32)
            self.pool_k, self.pool_v = self._jit_copy_pages(self.pool_k, self.pool_v, src, dst)

        B = self.max_slots
        # inactive rows keep the OOB sentinel so they own no pages
        tables = np.full((B, self.Rmax), self.n_pool_pages, np.int32)
        page_pos = np.zeros((B, self.Rmax), np.int32)
        lengths = np.zeros((B,), np.int32)
        tokens = np.zeros((B,), np.int32)
        for i in active:
            st = self._slots[i]
            if self.kv is not None:
                row = self.kv.table(st["seq"], self.Rmax)
            else:
                row = self.alloc.table(st["seq"].seq_id, self.Rmax)
            tables[i] = row
            page_pos[i] = np.arange(self.Rmax) * self.T  # positional pages (no ring)
            lengths[i] = st["length"] + len(st["generated"]) - 1
            tokens[i] = st["generated"][-1]

        logits, self.pool_k, self.pool_v, _ = self._jit_decode(
            self.params, self.pool_k, self.pool_v,
            jnp.asarray(tables), jnp.asarray(page_pos), jnp.asarray(lengths),
            jnp.asarray(tokens),
        )
        logits = np.asarray(logits)

        if self.kv is not None:
            # a head page that just FILLED becomes a published blob version
            # (write_async: the publish pipeline overlaps the next steps)
            for i in active:
                seq = self._slots[i]["seq"]
                if seq.length and seq.length % self.T == 0:
                    idx = seq.length // self.T - 1
                    if (
                        seq.page_addr[idx] is None
                        and idx not in self.kv.pending_pages(seq)
                    ):
                        sid = seq.slots[idx]
                        self.kv.publish_page_async(
                            seq, idx,
                            pack_kv_page(
                                self.pool_k[:, sid], self.pool_v[:, sid],
                                self.kv.store.page_size,
                            ),
                        )

        for i in active:
            st = self._slots[i]
            tok = self._sample(logits[i], st["req"].temperature)
            st["generated"].append(tok)
            if len(st["generated"]) >= st["req"].max_new_tokens:
                self._finish(i)
        return len(active)

    def _finish(self, slot: int) -> None:
        st = self._slots[slot]
        if self.kv is not None:
            self.kv.finish(st["seq"])
        else:
            self.alloc.finish(st["seq"].seq_id)
        self._done[st["req"].request_id] = Completion(
            st["req"].request_id,
            st["generated"],
            st["shared"],
            time.time() - st["t0"],
        )
        self._slots[slot] = None

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Completion]:
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and self._queue.empty():
                break
        return dict(self._done)
