"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model using
``lax.scan`` over layers (i.e., every serious JAX LLM) is undercounted by the
layer count. This module parses the post-SPMD HLO text and computes:

* **flops** — dot/conv FLOPs (2·M·N·K·batch), multiplied by the execution
  count of the enclosing computation (while bodies × trip count, nested scans
  multiply). Elementwise FLOPs are excluded (<2% for matmul-dominated LLM
  steps) — noted in EXPERIMENTS.md.
* **bytes** — naive HBM traffic: Σ over executed ops of (operand + result
  bytes), fusions counted as single ops (their internals live in registers),
  bookkeeping ops (tuple/gte/parameter/constant/bitcast) skipped.
* **collective_bytes** — result-shape bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute × execution count.

Operand shapes are resolved through a per-computation symbol table (compiled
HLO does not inline operand shapes). Validated against XLA's own
cost_analysis on scan-free programs (tests/test_hlo_cost.py).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(\(?[^=]*?)\s*([a-z][a-z0-9\-]*)\((.*)$")
_BODY_RE = re.compile(r"body=%?([\w\.\-_]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-_]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-_]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast", "bitcast-convert",
    "after-all", "partition-id", "replica-id", "opt-barrier", "copy", "copy-start",
    "copy-done", "iota",
    # control flow: the body computations carry the traffic, not the op itself
    "while", "conditional",
}


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _shape_elems(m) -> int:
    n = 1
    for d in _dims(m.group(2)):
        n *= d
    return n


def _shape_bytes(m) -> int:
    return _shape_elems(m) * _DTYPE_BYTES[m.group(1)]


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _strip_meta(line: str) -> str:
    line = _COMMENT_RE.sub("", line)
    i = line.find("metadata=")
    return line[:i] if i >= 0 else line


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_part: str  # text of result shape(s)
    args_part: str  # text after the opening paren (operands + attrs), metadata-stripped

    def result_bytes(self) -> int:
        return sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(self.result_part))

    def result_elems(self) -> int:
        ms = list(_SHAPE_RE.finditer(self.result_part))
        return _shape_elems(ms[0]) if ms else 0


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)  # op name -> result_part
    int_constants: List[int] = dataclasses.field(default_factory=list)

    def operand_names(self, op: Op) -> List[str]:
        # operands live before the first top-level ')'; attribute comp refs
        # (body=/calls=) come after — a close enough split for cost purposes.
        cut = op.args_part.split(")")[0]
        return _OPERAND_RE.findall(cut)

    def operand_bytes(self, op: Op) -> int:
        total = 0
        for name in self.operand_names(op):
            part = self.shapes.get(name)
            if part:
                total += sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(part))
        return total

    def operand_shape_dims(self, op: Op, index: int) -> List[int]:
        names = self.operand_names(op)
        if index >= len(names):
            return []
        part = self.shapes.get(names[index], "")
        ms = list(_SHAPE_RE.finditer(part))
        return _dims(ms[0].group(2)) if ms else []


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_HDR_RE.match(stripped)
            if m:
                current = Computation(m.group(2), is_entry=bool(m.group(1)))
                comps[current.name] = current
                continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(_strip_meta(line))
        if m:
            op = Op(m.group(1), m.group(3), m.group(2), m.group(4))
            current.ops.append(op)
            current.shapes[op.name] = op.result_part
            if op.opcode == "constant":
                cm = re.match(r"(\d+)\)", op.args_part)
                if cm:
                    current.int_constants.append(int(cm.group(1)))
    return comps


def _trip_count(comps: Dict[str, Computation], cond: Computation) -> int:
    """lax.scan conditions compare the induction var LT a constant. The
    constant is materialized as a `constant` op in the condition region (the
    compare itself may be wrapped in a fusion)."""
    consts = list(cond.int_constants)
    for op in cond.ops:
        if op.opcode == "fusion":
            m = _CALLS_RE.search(op.args_part)
            if m and m.group(1) in comps:
                consts.extend(comps[m.group(1)].int_constants)
    return max(consts) if consts else 1


def _fusion_slice_bytes(comps: Dict[str, Computation], op: Op) -> Optional[int]:
    """Dynamic-slice / dynamic-update-slice fusions touch only the SLICE, not
    the whole stacked operand (scan weights are (L, ...) but each iteration
    reads one layer). Counting full operands would overcount by ×L."""
    m = _CALLS_RE.search(op.args_part)
    if not m or m.group(1) not in comps:
        return None
    inner = comps[m.group(1)]
    total = 0
    found = False
    for iop in inner.ops:
        if iop.opcode == "dynamic-slice":
            total += 2 * iop.result_bytes()  # read slice + write result
            found = True
        elif iop.opcode == "dynamic-update-slice":
            names = inner.operand_names(iop)
            upd = inner.shapes.get(names[1], "") if len(names) > 1 else ""
            ub = sum(_shape_bytes(sm) for sm in _SHAPE_RE.finditer(upd))
            total += 2 * ub  # read update + write slice in place
            found = True
    return total if found else None


def _dot_flops(comp: Computation, op: Op) -> int:
    lhs = comp.operand_shape_dims(op, 0)
    cm = _CONTRACT_RE.search(op.args_part)
    contract = _dims(cm.group(1)) if cm else []
    k = 1
    for d in contract:
        if d < len(lhs):
            k *= lhs[d]
    return 2 * op.result_elems() * k


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns ``list[dict]`` on some jax
    versions and ``dict`` (or ``None``) on others — always yield a dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: Dict[str, float]
    collective_total: float
    collective_count: float
    while_trips: Dict[str, int]


def analyze_hlo(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = next((n for n, c in comps.items() if c.is_entry), None)
    if entry is None:
        entry = next((n for n in comps if "main" in n), next(iter(comps)))

    fusion_comps = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                m = _CALLS_RE.search(op.args_part)
                if m:
                    fusion_comps.add(m.group(1))

    exec_count: Dict[str, float] = defaultdict(float)
    while_trips: Dict[str, int] = {}

    def visit(name: str, count: float, depth=0):
        if name not in comps or count <= 0 or depth > 64:
            return
        exec_count[name] += count
        for op in comps[name].ops:
            if op.opcode == "while":
                b = _BODY_RE.search(op.args_part)
                c = _COND_RE.search(op.args_part)
                trips = _trip_count(comps, comps[c.group(1)]) if c and c.group(1) in comps else 1
                if b:
                    while_trips[b.group(1)] = trips
                    visit(b.group(1), count * trips, depth + 1)
                if c:
                    visit(c.group(1), count * trips, depth + 1)
            elif op.opcode == "fusion":
                m = _CALLS_RE.search(op.args_part)
                if m:
                    visit(m.group(1), count, depth + 1)
            elif op.opcode == "call":
                m = re.search(r"to_apply=%?([\w\.\-_]+)", op.args_part)
                if m:
                    visit(m.group(1), count, depth + 1)

    visit(entry, 1.0)

    flops = 0.0
    nbytes = 0.0
    coll = {k: 0.0 for k in COLLECTIVE_OPS}
    coll_count = 0.0
    for name, comp in comps.items():
        count = exec_count.get(name, 0.0)
        if count <= 0:
            continue
        in_fusion = name in fusion_comps
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops += count * _dot_flops(comp, op)
            if in_fusion:
                continue
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_OPS:
                if not op.opcode.endswith("-done"):
                    b = op.result_bytes()
                    # XLA:CPU promotes bf16 reductions/dots to f32 (TPU does
                    # both natively in bf16) — count promoted collectives at
                    # their true width: 'promoted' reducers, or operands that
                    # are just convert(bf16->f32) fusions.
                    if "promoted" in op.args_part:
                        b //= 2
                    elif "f32[" in op.result_part:
                        names = comp.operand_names(op)
                        if names and "convert" in names[0]:
                            b //= 2
                    coll[base] += count * b
                    coll_count += count
                continue
            if op.opcode in _SKIP_BYTES_OPS or op.opcode.endswith("-done"):
                continue
            if op.opcode == "fusion":
                sliced = _fusion_slice_bytes(comps, op)
                if sliced is not None:
                    nbytes += count * sliced
                    continue
            if op.opcode in ("dynamic-slice",):
                nbytes += count * 2 * op.result_bytes()
                continue
            nbytes += count * (op.result_bytes() + comp.operand_bytes(op))

    return HloCost(
        flops=flops,
        bytes=nbytes,
        collective_bytes=coll,
        collective_total=sum(coll.values()),
        collective_count=coll_count,
        while_trips=while_trips,
    )
