"""Input specs per (arch × shape): concrete arrays for smoke tests, or
``jax.ShapeDtypeStruct`` stand-ins for the dry-run (no allocation)."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def train_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    if cfg.input_kind == "tokens":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    if cfg.input_kind == "embeds":
        return {
            "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    if cfg.input_kind == "encdec":
        return {
            "enc_embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    raise ValueError(cfg.input_kind)


def prefill_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    spec = train_batch_spec(cfg, batch, seq)
    spec.pop("labels", None)
    return spec


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, mode: str, seed: int = 0):
    """Materialize a random batch matching the spec (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    spec = train_batch_spec(cfg, batch, seq) if mode == "train" else prefill_batch_spec(cfg, batch, seq)
    out: Dict[str, Any] = {}
    for name, s in spec.items():
        if s.dtype == jnp.int32:
            out[name] = jnp.asarray(rng.integers(0, cfg.vocab_size, s.shape), jnp.int32)
        else:
            out[name] = jnp.asarray(rng.normal(0, 1, s.shape), s.dtype)
    return out


def decode_tokens_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch,), jnp.int32)
