import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation) and dump memory / cost / collective
analysis for the roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape decode_32k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.jsonl
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_axis_info, make_production_mesh
from repro.models.config import ModelConfig, SHAPES, get_shape
from repro.models.lm import build_model
from repro.parallel import sharding as shd
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step

# ----------------------------- hardware constants (TPU v5e) -------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def should_skip(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.supports_500k:
        return "pure full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §4)"
    return None


# (collective byte accounting lives in repro.launch.hlo_cost — trip-count aware)

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


# ----------------------------- abstract state construction ---------------------------
def abstract_init(model, key):
    """(params ShapeDtypeStruct tree, logical-axes tree) without allocation."""
    captured = {}

    def f(k):
        p, a = model.init(k)
        captured["axes"] = a
        return p

    params_shape = jax.eval_shape(f, key)
    return params_shape, captured["axes"]


def opt_shardings_like(p_shard):
    return {
        "m": jax.tree.map(lambda s: s, p_shard),
        "v": jax.tree.map(lambda s: s, p_shard),
        "step": None,
    }


def build_lowerable(arch: str, shape_name: str, mesh):
    """Returns (lowered, meta) for one cell."""
    axis_info = make_axis_info(mesh)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params_shape, axes = abstract_init(model, key)
    p_shard = shd.param_shardings(params_shape, axes, cfg, axis_info)
    n_dev = mesh.size

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        o_shard = opt_shardings_like(p_shard)
        batch_spec = S.train_batch_spec(cfg, shape.global_batch, shape.seq_len)
        b_shard = shd.batch_shardings(batch_spec, cfg, axis_info)
        step = make_train_step(model, cfg, axis_info, AdamWConfig(), param_shardings=p_shard)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_shape, opt_shape, batch_spec)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.param_count(active_only=True) * tokens

    elif shape.kind == "prefill":
        batch_spec = S.prefill_batch_spec(cfg, shape.global_batch, shape.seq_len)
        b_shard = shd.batch_shardings(batch_spec, cfg, axis_info)
        fn = lambda p, b: model.prefill(p, b, axis_info)
        # output shardings: logits over batch; cache pools striped
        out_struct = jax.eval_shape(fn, params_shape, batch_spec)
        logits_shard = shd.batch_shardings(out_struct[0], cfg, axis_info)
        cache_shard = shd.cache_shardings(out_struct[1], cfg, axis_info)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard), out_shardings=(logits_shard, cache_shard))
        with mesh:
            lowered = jitted.lower(params_shape, batch_spec)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.param_count(active_only=True) * tokens

    else:  # decode
        pad = axis_info.n_page_shards
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len, pad_pages_to=pad)
        )
        cache_shard = shd.cache_shardings(cache_struct, cfg, axis_info)
        tok_spec = S.decode_tokens_spec(cfg, shape.global_batch)
        tok_shard = shd.batch_shardings(tok_spec, cfg, axis_info)
        fn = lambda p, c, t: model.decode_step(p, c, t, axis_info)
        out_struct = jax.eval_shape(fn, params_shape, cache_struct, tok_spec)
        logits_shard = shd.batch_shardings(out_struct[0], cfg, axis_info)
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, cache_shard, tok_shard),
            out_shardings=(logits_shard, cache_shard),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(params_shape, cache_struct, tok_spec)
        model_flops = 2 * cfg.param_count(active_only=True) * shape.global_batch

    return lowered, {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "n_devices": n_dev,
        "model_flops": model_flops,
        "params": cfg.param_count(),
        "active_params": cfg.param_count(active_only=True),
    }


def analyze(lowered, compiled, meta) -> Dict[str, Any]:
    from repro.launch import hlo_cost

    n_dev = meta["n_devices"]
    mem = compiled.memory_analysis()
    xla_cost = hlo_cost.normalize_cost_analysis(compiled.cost_analysis())
    cost = hlo_cost.analyze_hlo(compiled.as_text())
    hlo_flops = cost.flops  # per-device (post-SPMD module), trip-count-aware
    hlo_bytes = cost.bytes

    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    coll_s = cost.collective_total / ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=lambda k: terms[k] or 0.0)
    useful = meta["model_flops"] / (hlo_flops * n_dev) if hlo_flops > 0 else None

    return dict(
        meta,
        ok=True,
        bytes_per_device=dict(
            arguments=int(mem.argument_size_in_bytes),
            outputs=int(mem.output_size_in_bytes),
            temps=int(mem.temp_size_in_bytes),
            total=int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
            ),
        ),
        hlo_flops_per_device=hlo_flops,
        hlo_bytes_per_device=hlo_bytes,
        xla_flops_per_device=float(xla_cost.get("flops", -1.0)),
        collectives={k: v for k, v in cost.collective_bytes.items()},
        collective_count=cost.collective_count,
        roofline=dict(
            **terms,
            dominant=dominant,
            model_flops=meta["model_flops"],
            useful_flops_ratio=useful,
        ),
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    cfg = get_config(arch)
    skip = should_skip(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if skip:
        return dict(base, ok=True, skipped=skip)
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, meta = build_lowerable(arch, shape_name, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec = analyze(lowered, compiled, meta)
        rec.update(base, lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
        return rec
    except Exception as e:
        return dict(base, ok=False, error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=[s.name for s in SHAPES] + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp)
                line = json.dumps(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
                slim = {k: v for k, v in rec.items() if k not in ("traceback", "collectives")}
                print(json.dumps(slim), flush=True)
                if not rec.get("ok"):
                    print(rec.get("traceback", ""), file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
