"""Serving launcher: batched requests through the paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2-1b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_slots=args.slots, n_pages=512)

    rng = np.random.default_rng(0)
    shared_prefix = rng.integers(0, cfg.vocab_size, 16).tolist()
    t0 = time.time()
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size, 8).tolist()
        engine.submit(Request(i, shared_prefix + tail, max_new_tokens=args.max_new))
    done = engine.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in done.values())
    shared = sum(c.prefill_skipped_tokens for c in done.values())
    print(f"{len(done)} completions, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s), prefix-cache hits: {shared} tokens")
    print("pool stats:", engine.alloc.stats)


if __name__ == "__main__":
    main()
