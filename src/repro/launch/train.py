"""Multi-host training launcher with fault tolerance and elastic restart.

Responsibilities:
  * ``jax.distributed.initialize`` from env (COORDINATOR_ADDRESS /
    NUM_PROCESSES / PROCESS_ID — SLURM-style), or single-process fallback;
  * build an elastic mesh from whatever devices survived
    (``make_mesh_for_devices``), so a restart after node loss re-meshes and
    the checkpoint is resharded onto the new topology;
  * versioned incremental checkpoints (``storage/checkpoint.py``): atomic
    publication means a crash mid-save can never corrupt the restore point;
  * deterministic data order resumption (``data/pipeline.py`` ``set_step``);
  * optional int8 cross-pod gradient compression (``--compress-grads``).

Example (CPU, reduced config — exercised by examples/train_lm.py):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2-1b --smoke \
      --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.cluster import Cluster, Session
from repro.data.pipeline import PipelineConfig, TokenPipeline, write_token_corpus
from repro.launch.mesh import make_axis_info, make_mesh_for_devices
from repro.models.lm import build_model
from repro.parallel import sharding as shd
from repro.storage.checkpoint import BlobCheckpointer
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def maybe_init_distributed() -> None:
    addr = os.environ.get("COORDINATOR_ADDRESS")
    if addr:
        jax.distributed.initialize(
            coordinator_address=addr,
            num_processes=int(os.environ["NUM_PROCESSES"]),
            process_id=int(os.environ["PROCESS_ID"]),
        )


def train(
    arch: str,
    *,
    smoke: bool = False,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    model_parallel: int = 1,
    checkpoint_every: int = 20,
    restore: bool = False,
    seed: int = 0,
    lr: float = 3e-4,
    session: Optional[Session] = None,
    fail_at_step: Optional[int] = None,  # fault-injection hook for tests
):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    import dataclasses

    cfg = dataclasses.replace(cfg, grad_accum=min(cfg.grad_accum, max(batch // 2, 1)))

    mesh = make_mesh_for_devices(model_parallel=model_parallel)
    axis_info = make_axis_info(mesh) if mesh.size > 1 else None

    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params, axes = model.init(key)
    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 10, 1))
    step_fn = make_train_step(model, cfg, axis_info, opt_cfg)
    if axis_info is not None:
        p_shard = shd.param_shardings(params, axes, cfg, axis_info)
        o_shard = {"m": p_shard, "v": p_shard, "step": None}
        jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, None),
                         out_shardings=(p_shard, o_shard, None), donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    # ---- data: tokenized corpus in the blob store ----
    session = session or Cluster(
        n_data_providers=4, n_metadata_providers=4
    ).session()
    rng = np.random.default_rng(seed)
    n_tokens = max(batch * (seq + 1) * 64, 1 << 16)
    # learnable synthetic corpus: noisy affine bigram process (a uniform
    # random stream has irreducible CE = ln(vocab) and nothing to learn)
    corpus = np.empty(n_tokens, dtype=np.int32)
    corpus[0] = 1
    nxt = (np.arange(cfg.vocab_size, dtype=np.int64) * 31 + 7) % cfg.vocab_size
    noise = rng.random(n_tokens) < 0.1
    rand_toks = rng.integers(0, cfg.vocab_size, n_tokens)
    for i in range(1, n_tokens):
        corpus[i] = rand_toks[i] if noise[i] else nxt[corpus[i - 1]]
    corpus_handle = write_token_corpus(session, corpus)
    pipe = TokenPipeline(
        corpus_handle, n_tokens,
        PipelineConfig(batch_per_rank=batch, seq_len=seq, n_ranks=1, rank=0, seed=seed),
    )

    # ---- checkpointing ----
    ckpt = BlobCheckpointer(session, {"params": params, "opt": opt_state}, page_size=1 << 16)
    start_step = 0
    if restore and ckpt.checkpoints:
        state = ckpt.restore()
        params, opt_state = state["params"], state["opt"]
        start_step = int(np.asarray(opt_state["step"]))
        pipe.set_step(start_step)

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch_np = pipe.batch_at(step)
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = jitted(params, opt_state, batch_dev)
        losses.append(float(metrics["loss"]))
        if (step + 1) % checkpoint_every == 0 or step + 1 == steps:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if step % 10 == 0:
            print(
                f"step {step} loss {losses[-1]:.4f} "
                f"({(time.time() - t0) / max(step - start_step + 1, 1):.2f}s/step)",
                flush=True,
            )
    return {
        "losses": losses,
        "params": params,
        "opt_state": opt_state,
        "checkpointer": ckpt,
        "session": session,
        "pipeline": pipe,
        "final_step": steps,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    maybe_init_distributed()
    out = train(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, model_parallel=args.model_parallel,
        checkpoint_every=args.checkpoint_every, restore=args.restore, lr=args.lr,
    )
    print(f"final loss {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
