"""Production mesh construction. A FUNCTION (not module-level state) so that
importing this module never touches jax device state."""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.parallel.axisinfo import AxisInfo


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod, 256 chips) or 2×16×16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_axis_info(mesh) -> AxisInfo:
    names = mesh.axis_names
    if "pod" in names:
        return AxisInfo(mesh, batch_axes=("pod", "data"), model_axis="model")
    return AxisInfo(mesh, batch_axes=("data",), model_axis="model")


def make_mesh_for_devices(n_devices: Optional[int] = None, model_parallel: int = 1):
    """Elastic mesh for whatever devices exist (training launcher / tests)."""
    n = n_devices or len(jax.devices())
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by model_parallel={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
