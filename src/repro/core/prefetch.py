"""Adaptive readahead for the streaming read plane (beyond-paper scaling).

The paper's READ protocol is demand-driven: nothing moves until a client asks.
The workloads that hammer this reproduction — supernovae detectors sweeping
MB-scale windows out of each freshly published sky frame (§IV) — are highly
predictable, though, and BlobSeer-style deployments win by warming a RAM tier
before the detectors ask. Two predictors live here:

* :class:`StridePrefetcher` — a per-:class:`~repro.core.cluster.Session`
  sequential/stride detector over read offsets. Once a stable forward stride
  is observed it issues *bounded* readahead of the next pages into the
  cluster's shared cache tier, through the same frontier-validated fill path
  every read uses. The prefetcher only ever fetches pages of the version the
  session is already reading — a version that was resolved and validated as
  published — so it can never pull unpublished data past the publish
  frontier, and it clamps readahead at the blob end. Readahead is issued on
  the cluster's *auxiliary* pool and never blocks the read path: when the
  in-flight budget is exhausted the observation is simply dropped.

* :class:`WatchWarmer` — a cluster-level warmer that subscribes to a blob's
  publications (:class:`~repro.core.cluster.VersionWatch`) and fills the
  shared tier with the *hottest* pages of each freshly published version
  before detector sessions read it, reusing the
  :class:`~repro.core.replica_balancer.ReplicaBalancer`'s read-heat counters
  as the prior (falling back to the version's own freshly written interval
  while no heat has accumulated yet). The warmer drives a private session's
  read path, so every fill is frontier-validated and single-flighted like
  any other read: it structurally cannot warm an unpublished version, GC
  purges warmed pages like any cached page, and snapshot pins keep pinned
  versions readable exactly as they do for demand reads.

Both predictors are best-effort: a failed fill aborts its single-flight
entries (so concurrent demand readers retry or surface the same provider
error they would have hit themselves) and is otherwise dropped.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, TimeoutError as FutureTimeout
from typing import TYPE_CHECKING, Dict, List, Optional, Set

from repro.analysis.lockwatch import make_condition, make_lock

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (cluster imports us)
    from repro.core.cluster import Cluster, Session


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    """Knobs for :class:`StridePrefetcher`.

    ``min_run``: consecutive same-stride observations before readahead fires
    (one coincidental repeat is not a pattern). ``window_pages``: how many
    pages each readahead issue covers — the depth of the pipeline in pages.
    ``max_inflight``: bound on concurrent readahead fills per session; an
    observation arriving at the bound is dropped, never queued, so a slow
    tier can't build an unbounded fetch backlog.
    """

    min_run: int = 2
    window_pages: int = 32
    max_inflight: int = 2


@dataclasses.dataclass
class _BlobStride:
    """Per-(blob, version) detector state."""

    version: int
    last_first: int  # first page of the previous observed read
    stride: int = 0
    run: int = 0
    #: next page the prefetcher has NOT yet issued readahead for — keeps
    #: overlapping observations from re-fetching the same pages
    frontier: int = 0


class StridePrefetcher:
    """Sequential/stride read detector with bounded shared-tier readahead."""

    def __init__(
        self, session: "Session", config: Optional[PrefetchConfig] = None
    ) -> None:
        self._session = session
        self.config = config or PrefetchConfig()
        self._lock = make_lock("StridePrefetcher._lock")
        self._state: Dict[int, _BlobStride] = {}
        self._inflight: Set[Future] = set()
        #: readahead issues / pages covered / observations dropped at the
        #: in-flight bound — benchmark & test introspection
        self.issued = 0
        self.pages_requested = 0
        self.skipped_inflight = 0

    def observe(
        self,
        blob_id: int,
        version: int,
        first_page: int,
        end_page: int,
        total_pages: int,
        page_size: int,
    ) -> None:
        """Feed one read's page span ``[first_page, end_page)`` of a resolved
        *published* ``version`` to the detector; maybe issue readahead.
        Cheap (a few dict ops under a lock) and non-blocking — called inline
        by the read path before its own fetch, so readahead overlaps the
        very read that triggered it."""
        cfg = self.config
        fut: Optional[Future] = None
        with self._lock:
            st = self._state.get(blob_id)
            if st is None or st.version != version:
                # new blob or new version: start a fresh detector window
                self._state[blob_id] = _BlobStride(
                    version=version, last_first=first_page, frontier=end_page
                )
                return
            stride = first_page - st.last_first
            if stride > 0 and stride == st.stride:
                st.run += 1
            else:
                # broken or backward pattern: re-arm (a backward/random jump
                # resets the readahead frontier to the new position)
                st.run = 1 if stride > 0 else 0
                st.frontier = end_page
            st.stride = stride
            st.last_first = first_page
            st.frontier = max(st.frontier, end_page)
            if st.run < cfg.min_run:
                return
            start = st.frontier
            # bounded pipeline depth: never run more than the in-flight
            # budget's worth of windows ahead of the reader — an unbounded
            # frontier on a long scan would evict prefetched pages before
            # the reader reaches them and double the provider traffic
            horizon = end_page + cfg.window_pages * cfg.max_inflight
            stop = min(start + cfg.window_pages, horizon, total_pages)
            if start >= stop:
                return
            if len(self._inflight) >= cfg.max_inflight:
                self.skipped_inflight += 1
                return
            try:
                fut = self._session.cluster._aux_submit(
                    self._session._prefetch_fill,
                    blob_id,
                    version,
                    list(range(start, stop)),
                    total_pages,
                    page_size,
                )
            except RuntimeError:
                return  # aux pool shut down mid-close: drop, never raise
            st.frontier = stop
            self.issued += 1
            self.pages_requested += stop - start
            self._inflight.add(fut)
        fut.add_done_callback(self._discard)

    def _discard(self, fut: Future) -> None:
        with self._lock:
            self._inflight.discard(fut)

    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    def wait_idle(self, timeout: float = 30.0) -> bool:
        """Join all outstanding readahead tasks (tests/benchmarks only —
        production readers never wait on the prefetcher)."""
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                pending = list(self._inflight)
            if not pending:
                return True
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            try:
                pending[0].exception(timeout=remaining)
            except FutureTimeout:
                return False


class WatchWarmer:
    """Publish-driven shared-tier warmer for one blob.

    A daemon thread waits on the blob's :class:`VersionWatch`; when versions
    publish it drains to the newest one (warming a superseded version would
    only evict pages detectors are about to replace) and fills the shared
    tier with up to ``top_pages`` pages of it: the balancer's hottest page
    offsets first, then the version's own freshly written interval. With
    ``frame_versions=N`` set, only every N-th version is warmed — the paper's
    application publishes one version per sky *region*, so a frame boundary
    is every ``n_regions`` versions and warming mid-frame versions would be
    wasted traffic.

    Create via :meth:`Cluster.warm_on_publish`, which also stops the warmer
    on cluster close; ``wait_warmed`` lets tests and benchmark harnesses
    rendezvous with a fill deterministically.
    """

    def __init__(
        self,
        cluster: "Cluster",
        blob_id: int,
        top_pages: int = 256,
        frame_versions: Optional[int] = None,
        poll_seconds: float = 0.05,
    ) -> None:
        self.cluster = cluster
        self.blob_id = blob_id
        self.top_pages = top_pages
        self.frame_versions = frame_versions
        self._poll = poll_seconds
        # the warmer's private client: no private cache, so every fill lands
        # in the cluster's SHARED tier through the frontier-validated path
        self._session = cluster.session(cache_bytes=0)
        self._handle = self._session.open(blob_id)
        self._watch = self._handle.watch()
        self._stop = threading.Event()
        self._cv = make_condition("WatchWarmer._cv")
        self._warmed: Dict[int, int] = {}  # version -> pages filled
        self.pages_warmed = 0
        self._thread = threading.Thread(
            target=self._run, name=f"watch-warmer-{blob_id}", daemon=True
        )
        self._thread.start()

    # -- the warming loop ----------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            v = self._watch.next(timeout=self._poll)
            if v is None:
                continue
            newest = max([v] + self._watch.drain())
            if self.frame_versions:
                newest = (newest // self.frame_versions) * self.frame_versions
                if newest == 0 or newest in self._warmed:
                    continue
            try:
                n = self._warm(newest)
            except BaseException:
                n = 0  # best-effort: a failed warm is just a cold first read
            with self._cv:
                self._warmed[newest] = n
                self.pages_warmed += n
                self._cv.notify_all()

    def _warm(self, version: int) -> int:
        total_pages = self._handle.total_pages
        pages = self._pick_pages(version, total_pages)
        if not pages:
            return 0
        return self._session._prefetch_fill(
            self.blob_id, version, pages, total_pages, self._handle.page_size
        )

    def _pick_pages(self, version: int, total_pages: int) -> List[int]:
        """Hottest page offsets by read heat, topped up from the version's
        own written interval while the heat counters are still cold."""
        pages: List[int] = []
        balancer = self.cluster.replica_balancer
        if balancer is not None:
            pages = [
                p
                for p in balancer.hottest_page_offsets(self.blob_id, self.top_pages)
                if p < total_pages
            ]
        if len(pages) < self.top_pages:
            try:
                off, size = self.cluster.version_manager.interval_of(
                    self.blob_id, version
                )
            except KeyError:
                off = size = 0
            seen = set(pages)
            for p in range(off, min(off + size, total_pages)):
                if len(pages) >= self.top_pages:
                    break
                if p not in seen:
                    pages.append(p)
        return pages[: self.top_pages]

    # -- rendezvous / introspection ------------------------------------------
    def wait_warmed(self, version: int, timeout: Optional[float] = None) -> bool:
        """Block until a warm pass for ``version`` (or any newer one) has
        completed; ``False`` on timeout."""
        with self._cv:
            return self._cv.wait_for(
                lambda: any(v >= version for v in self._warmed), timeout
            )

    def warmed_versions(self) -> Dict[int, int]:
        with self._cv:
            return dict(self._warmed)

    def stop(self, timeout: float = 10.0) -> None:
        """Stop the warming thread and release the warmer's session
        (idempotent; called by :meth:`Cluster.close`). The join is bounded by
        ``timeout`` — a warm pass wedged on a dead provider must not hang the
        caller's close; the daemon thread then dies with the process."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join(timeout=timeout)
        self._session.close()
