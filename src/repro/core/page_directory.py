"""Cluster-wide content-addressed page directory (the serving plane's
cross-user prefix cache, kept storage-generic).

The paper's snapshot model makes *published* pages immutable, so a page's
identity can be its content: this directory maps an integer content key (the
KV plane uses a token-chain hash) to the ``(blob_id, version, page)`` triple
where those bytes live. Any session on the cluster that resolves the same
key reads the same stored page — through the node's shared cache tier — so N
clients sharing a prompt prefix cost one stored copy and (at most) one
provider fetch, the paper's "sharing common parts of snapshots" applied to
inference serving.

GC safety is snapshot pinning, not refcounts on bytes: publishing an entry
pins its version via :meth:`Cluster.pin_published` (which *validates the
publish frontier first* — an unpublished version can never be registered, so
a cross-session read through the directory is impossible before the writer
publishes). Eviction drops the pin; readers that still hold the entry's
refcount keep it alive, and readers that pinned their own covering version
keep the *bytes* alive even after eviction, because a pinned version's tree
reaches every page written at-or-before it.

Locking: ``PageDirectory._lock`` (level 3) guards only dict/LRU state. Pins
are taken *before* the lock (they serialize against GC on the cluster's
level-1 guard) and dropped *after* it; eviction hooks fire outside the lock.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, TYPE_CHECKING, Tuple

from repro.analysis.lockwatch import make_lock

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (cluster owns us)
    from repro.core.cluster import Cluster


@dataclasses.dataclass(frozen=True)
class PageAddress:
    """Where one published page's bytes live: immutable forever (the paper's
    versioned-WRITE guarantee), so the triple can be shared freely across
    sessions and cached under a stable key."""

    blob_id: int
    version: int
    page: int


class _Entry:
    __slots__ = ("address", "refcount")

    def __init__(self, address: PageAddress) -> None:
        self.address = address
        self.refcount = 0


class PageDirectory:
    """Content key → :class:`PageAddress` registry with per-entry refcounts,
    LRU eviction of unreferenced entries, and version pinning.

    ``on_evict`` hooks (see :meth:`add_evict_hook`) let a page-pool owner
    (e.g. the blob-backed KV store) return slot bookkeeping when the
    directory drops an entry; hooks run outside the directory lock."""

    def __init__(self, cluster: "Cluster", capacity: int = 4096) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.cluster = cluster
        self.capacity = capacity
        self._lock = make_lock("PageDirectory._lock")
        self._entries: "OrderedDict[int, _Entry]" = OrderedDict()
        self._evict_hooks: List[Callable[[int, PageAddress], None]] = []
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- eviction hooks -------------------------------------------------------
    def add_evict_hook(self, hook: Callable[[int, PageAddress], None]) -> None:
        with self._lock:
            self._evict_hooks.append(hook)

    def _fire_evictions(self, victims: List[Tuple[int, PageAddress]]) -> None:
        """Unpin + notify for evicted entries — NEVER under ``_lock`` (hooks
        take their owners' locks; the unpin takes the cluster pin table)."""
        with self._lock:
            hooks = list(self._evict_hooks)
        for key, address in victims:
            self.cluster.unpin_version(address.blob_id, address.version)
            for hook in hooks:
                hook(key, address)

    # -- registration ---------------------------------------------------------
    def publish(
        self, key: int, blob_id: int, version: int, page: int
    ) -> PageAddress:
        """Register ``key`` → ``(blob_id, version, page)``. The version is
        validated against the publish frontier and snapshot-pinned *before*
        the entry becomes visible — registering an unpublished (or abandoned)
        version raises, which is what makes a cross-session read of
        unpublished data through the directory impossible by construction.

        Returns the winning address: on a registration race the FIRST entry
        for ``key`` is kept (its pages are already shared) and the loser's
        pin is dropped."""
        # pin first (validates published + serializes against GC); only then
        # expose the entry — a reader can never resolve an unpinned address
        self.cluster.pin_published(blob_id, version)
        address = PageAddress(blob_id, version, page)
        victims: List[Tuple[int, PageAddress]] = []
        with self._lock:
            existing = self._entries.get(key)
            if existing is not None:
                self._entries.move_to_end(key)
                winner = existing.address
            else:
                self._entries[key] = _Entry(address)
                winner = address
                # soft capacity: evict unreferenced LRU entries; referenced
                # entries may push the directory over budget until released
                over = len(self._entries) - self.capacity
                if over > 0:
                    for k in list(self._entries):
                        if over <= 0:
                            break
                        if k != key and self._entries[k].refcount == 0:
                            victims.append((k, self._entries.pop(k).address))
                            over -= 1
        if winner is not address:
            self.cluster.unpin_version(blob_id, version)
        if victims:
            self.evictions += len(victims)
            self._fire_evictions(victims)
        return winner

    # -- lookup ---------------------------------------------------------------
    def acquire(self, key: int) -> Optional[PageAddress]:
        """Resolve ``key`` and take a refcount on the entry (it cannot be
        evicted until :meth:`release`); ``None`` on miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            entry.refcount += 1
            self._entries.move_to_end(key)
            self.hits += 1
            return entry.address

    def peek(self, key: int) -> Optional[PageAddress]:
        """Resolve without refcounting or LRU side effects (introspection)."""
        with self._lock:
            entry = self._entries.get(key)
            return entry.address if entry is not None else None

    def release(self, key: int) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and entry.refcount > 0:
                entry.refcount -= 1

    # -- eviction under pressure ---------------------------------------------
    def evict_unreferenced(
        self, n: int = 1, blob_id: Optional[int] = None
    ) -> int:
        """Drop up to ``n`` unreferenced entries, LRU-first (optionally only
        entries of ``blob_id`` — a page pool reclaiming its own slots).
        Returns how many were evicted; 0 means every entry is in use."""
        victims: List[Tuple[int, PageAddress]] = []
        with self._lock:
            for key in list(self._entries):
                if len(victims) >= n:
                    break
                entry = self._entries[key]
                if entry.refcount:
                    continue
                if blob_id is not None and entry.address.blob_id != blob_id:
                    continue
                victims.append((key, self._entries.pop(key).address))
        if victims:
            self.evictions += len(victims)
            self._fire_evictions(victims)
        return len(victims)

    # -- introspection --------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: int) -> bool:
        with self._lock:
            return key in self._entries

    def addresses(self) -> Dict[int, PageAddress]:
        """Snapshot of the full mapping (tests / invariant checks)."""
        with self._lock:
            return {k: e.address for k, e in self._entries.items()}

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
