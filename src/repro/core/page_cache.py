"""Client-side versioned page cache (scaling layer over the paper's design).

The paper's key property — a *published* version is immutable, its metadata
tree and data pages can never change (§III.C) — makes client-side caching
trivially coherent: a page keyed by ``(blob_id, version, page_index)`` is
valid forever, so the cache needs no invalidation protocol at all. Snapshot
re-reads (the supernovae detector differencing overlapping sky windows) then
hit RAM instead of issuing provider RPCs.

Two mechanisms live here:

* a thread-safe, byte-budgeted LRU over immutable pages;
* *single-flight* miss handling: when many concurrent readers miss on the
  same page, exactly one of them (the *leader*) fetches it from the provider
  while the others wait on the in-flight entry — N concurrent readers of a
  cold hot-window cost one provider fetch per page, not N.

Pages enter the cache from two directions, both coherent for the same
reason — a version's page content is fixed the moment its data is stored,
before it even publishes:

* the read path caches fetched pages of *published* versions (reads of
  unpublished versions are rejected before the cache is consulted);
* the write path **writes through**: a successful ``writev`` inserts its own
  just-stored pages under its freshly assigned versions, so a writer's
  re-reads of its own data are RAM hits with no provider round-trip. Readers
  still cannot *see* those versions until the version manager publishes
  them — visibility is gated upstream, never by the cache.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.dht import TrafficStats

#: Cache key: (blob_id, version, page_index).
CacheKey = Tuple[int, int, int]

#: Budget charge for an implicit zero page: all zero-page entries share one
#: read-only buffer, so their marginal memory cost is just the LRU slot —
#: caching them skips the metadata re-traversal on repeat sparse reads
#: without letting them evict genuinely expensive provider-fetched pages.
ZERO_PAGE_CHARGE = 64


class _Flight:
    """An in-flight fetch: leader fulfills/aborts, followers wait.

    ``gen`` stamps the cache generation the flight was planned under: a
    purge (:meth:`PageCache.clear` / :meth:`PageCache.drop_versions`)
    advances the generation, so a fill that was already in flight when GC
    purged its version wakes its waiters but is NOT inserted — without this,
    the stale insert would silently resurrect a collected version in the
    cache the purge just scrubbed."""

    __slots__ = ("event", "page", "error", "gen")

    def __init__(self, gen: int = 0) -> None:
        self.event = threading.Event()
        self.page: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self.gen = gen


@dataclasses.dataclass
class FetchPlan:
    """Partition of a lookup batch: RAM hits, keys this caller must fetch
    (it is the single-flight leader for them), and keys being fetched by
    concurrent leaders (wait on the flight)."""

    hits: Dict[CacheKey, np.ndarray]
    owned: List[CacheKey]
    waits: Dict[CacheKey, "_Flight"]


class PageCache:
    """Byte-budgeted LRU of immutable published pages, with single-flight."""

    def __init__(self, capacity_bytes: int, stats: Optional[TrafficStats] = None) -> None:
        if capacity_bytes <= 0:
            raise ValueError("capacity_bytes must be positive")
        self.capacity_bytes = capacity_bytes
        self.stats = stats or TrafficStats()
        self._lock = make_lock("PageCache._lock")
        #: key -> (page, budget charge); the charge is usually page.nbytes
        #: but nominal for entries sharing a buffer (zero pages)
        self._lru: "OrderedDict[CacheKey, Tuple[np.ndarray, int]]" = OrderedDict()
        self._inflight: Dict[CacheKey, _Flight] = {}
        self._used_bytes = 0
        self.evictions = 0
        #: purge generation — bumped by clear()/drop_versions() so in-flight
        #: fills planned before a purge cannot re-insert after it
        self._gen = 0

    # -- bulk lookup (the readv path) ------------------------------------------
    def plan(self, keys: Sequence[CacheKey], record: bool = True) -> FetchPlan:
        """Classify ``keys`` in one lock pass. The caller MUST eventually
        :meth:`fulfill` or :meth:`abort` every key in ``plan.owned`` — even on
        error paths — or concurrent waiters block forever.

        ``record=False`` skips the hit/miss stats recording — a session
        composing this cache into a multi-tier stack attributes hits and
        misses itself (per-session AND cluster-aggregate), so the cache must
        not double-count them here."""
        hits: Dict[CacheKey, np.ndarray] = {}
        owned: List[CacheKey] = []
        owned_set: set = set()
        waits: Dict[CacheKey, _Flight] = {}
        with self._lock:
            for key in keys:
                # a duplicate key must not land in waits for a flight this
                # very call created (self-deadlock for callers that drain
                # waits before fulfilling owned)
                if key in hits or key in waits or key in owned_set:
                    continue
                entry = self._lru.get(key)
                if entry is not None:
                    self._lru.move_to_end(key)
                    hits[key] = entry[0]
                    continue
                flight = self._inflight.get(key)
                if flight is not None:
                    waits[key] = flight
                else:
                    self._inflight[key] = _Flight(self._gen)
                    owned.append(key)
                    owned_set.add(key)
        if record:
            self.stats.record_cache(hits=len(hits), misses=len(owned) + len(waits))
        return FetchPlan(hits=hits, owned=owned, waits=waits)

    def fulfill(self, key: CacheKey, page: np.ndarray, charge: Optional[int] = None) -> None:
        """Leader completed the fetch: cache the page, wake waiters.

        ``charge`` overrides the budget accounting for this entry (default:
        ``page.nbytes``) — pass :data:`ZERO_PAGE_CHARGE` for implicit zero
        pages, whose buffer is shared across all entries."""
        page = page.view()
        page.flags.writeable = False  # cached pages are immutable
        with self._lock:
            flight = self._inflight.pop(key, None)
            # a fill planned before a purge must not re-insert after it: the
            # waiters still get their page (they validated the version before
            # the purge, like any read already in progress at GC time), but
            # the cache stays scrubbed
            if flight is None or flight.gen == self._gen:
                self._insert(
                    key, page, page.nbytes if charge is None else charge
                )
        if flight is not None:
            flight.page = page
            flight.event.set()

    def abort(self, key: CacheKey, error: BaseException) -> None:
        """Leader failed: propagate the error to waiters, cache nothing."""
        with self._lock:
            flight = self._inflight.pop(key, None)
        if flight is not None:
            flight.error = error
            flight.event.set()

    def wait(self, key: CacheKey, flight: _Flight, timeout: Optional[float] = None) -> np.ndarray:
        """Follower path: block until the leader resolves ``key``."""
        if not flight.event.wait(timeout):
            raise TimeoutError(f"page fetch for {key} did not complete")
        if flight.error is not None:
            raise flight.error
        assert flight.page is not None
        return flight.page

    def get_many(self, keys: Sequence[CacheKey]) -> Dict[CacheKey, np.ndarray]:
        """Bulk hit-only lookup in ONE lock pass (no single-flight, no stats):
        the private-tier probe of a session's multi-tier read path — misses
        simply fall through to the shared tier."""
        hits: Dict[CacheKey, np.ndarray] = {}
        with self._lock:
            for key in keys:
                entry = self._lru.get(key)
                if entry is not None:
                    self._lru.move_to_end(key)
                    hits[key] = entry[0]
        return hits

    # -- simple single-page API (tests, boundary merges) -----------------------
    def get(self, key: CacheKey) -> Optional[np.ndarray]:
        with self._lock:
            entry = self._lru.get(key)
            if entry is None:
                return None
            self._lru.move_to_end(key)
            return entry[0]

    def put(self, key: CacheKey, page: np.ndarray) -> None:
        page = page.view()
        page.flags.writeable = False
        with self._lock:
            self._insert(key, page, page.nbytes)

    def put_many(self, items: Sequence[Tuple[CacheKey, np.ndarray]]) -> None:
        """Bulk insert under ONE lock acquisition — the write-through path of
        ``writev`` inserts every page of a patch batch in one pass."""
        frozen = []
        for key, page in items:
            page = page.view()
            page.flags.writeable = False
            frozen.append((key, page))
        with self._lock:
            for key, page in frozen:
                self._insert(key, page, page.nbytes)

    # -- internals --------------------------------------------------------------
    def _insert(self, key: CacheKey, page: np.ndarray, charge: int) -> None:
        if charge > self.capacity_bytes:
            return  # entry can never fit; don't wipe the whole cache for it
        old = self._lru.pop(key, None)
        if old is not None:
            self._used_bytes -= old[1]
        self._lru[key] = (page, charge)
        self._used_bytes += charge
        while self._used_bytes > self.capacity_bytes:
            _, (_, evicted_charge) = self._lru.popitem(last=False)
            self._used_bytes -= evicted_charge
            self.evictions += 1

    # -- introspection -----------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def __contains__(self, key: CacheKey) -> bool:
        with self._lock:
            return key in self._lru

    def used_bytes(self) -> int:
        with self._lock:
            return self._used_bytes

    def cached_versions(self, blob_id: int) -> List[int]:
        """Distinct versions of ``blob_id`` with at least one cached page."""
        with self._lock:
            return sorted({k[1] for k in self._lru if k[0] == blob_id})

    def drop_versions(
        self, blob_id: int, keep: set, max_version: Optional[int] = None
    ) -> int:
        """GC coherence hook: purge cached pages of ``blob_id`` whose version
        is not in ``keep``. ``max_version`` (the publish frontier at GC time)
        protects versions above it — in-flight write-through entries whose
        backing pages GC never touches. Returns the number of pages
        dropped."""
        with self._lock:
            # invalidate in-flight fills too: a leader that planned a doomed
            # version's page before this purge may fulfill after it
            self._gen += 1
            doomed = [
                k
                for k in self._lru
                if k[0] == blob_id
                and k[1] not in keep
                and (max_version is None or k[1] <= max_version)
            ]
            for key in doomed:
                self._used_bytes -= self._lru.pop(key)[1]
            return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._gen += 1  # fence in-flight fills out of the emptied cache
            self._lru.clear()
            self._used_bytes = 0
