"""Data providers + provider manager (paper §III.A).

Data providers store pages in RAM. The provider manager tracks registered
providers and, per WRITE, picks which providers receive the freshly written
pages using a load-balancing strategy (least-loaded, ties broken by provider
id — "some strategy that favors global load balancing").

Providers may join and leave dynamically; page replication (``replication``)
plus replica fallback on read provides the fault tolerance the paper defers to
future work.

Placement is a lazy min-heap over ``(load, provider_id)``: allocating a page
pops the ``replication`` least-loaded providers and pushes them back with
their load incremented, so a bulk allocation of ``n`` pages costs
O(n·replication·log P) heap operations instead of the O(n·P·log P) of a
per-page full sort. Stale heap entries (left behind by ``release`` or
membership churn) are discarded on pop; every push/pop is counted in
``placement_ops`` so tests can assert the complexity bound.
"""

from __future__ import annotations

import heapq
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.dht import ProviderFailed, TrafficStats
from repro.core.segment_tree import PageRef


class DataProvider:
    """RAM page store. Pages are immutable once stored (COW discipline).

    All page-map accesses are serialized on a per-provider lock, so concurrent
    ``put_pages``/``delete_pages`` never race ``used_bytes``/``n_pages``
    iterating the dict. ``page_service_seconds`` > 0 models a provider with
    finite service bandwidth: each request holds the lock for that long per
    page transferred (the sleep releases the GIL, so *different* providers
    still serve in parallel — exactly the paper's network model, where a hot
    provider is the bottleneck and spreading load across providers helps).
    """

    def __init__(self, provider_id: int, page_service_seconds: float = 0.0) -> None:
        self.provider_id = provider_id
        self.page_service_seconds = page_service_seconds
        self._pages: Dict[int, np.ndarray] = {}
        self._lock = make_lock("DataProvider._lock")
        self.failed = False

    def _serve(self, n_pages: int) -> None:
        if self.page_service_seconds > 0.0 and n_pages > 0:
            time.sleep(self.page_service_seconds * n_pages)

    def set_failed(self, failed: bool) -> None:
        """Flip the failure-injection flag under this provider's own lock, so
        the transition serializes against in-flight ``put_pages``/``get_pages``
        (which check ``failed`` under the same lock): a request observes the
        provider strictly before or strictly after the transition, never a
        torn mid-request flip."""
        with self._lock:
            self.failed = failed

    def put_pages(self, items: Sequence[Tuple[int, np.ndarray]]) -> None:
        """Store pages zero-copy: the given arrays (typically read-only views
        into a writer's frozen source buffer) are referenced, never copied.
        Each stored page is marked read-only here, so the COW discipline is
        enforced at the store boundary no matter what the caller passed."""
        with self._lock:
            if self.failed:
                raise ProviderFailed(f"data provider {self.provider_id} is down")
            for page_key, data in items:
                data.flags.writeable = False
                self._pages[page_key] = data
            self._serve(len(items))

    def get_page(self, page_key: int) -> np.ndarray:
        with self._lock:
            if self.failed:
                raise ProviderFailed(f"data provider {self.provider_id} is down")
            page = self._pages[page_key]
            self._serve(1)
            return page

    def get_pages(self, page_keys: Sequence[int]) -> List[np.ndarray]:
        """One aggregated RPC for many pages (paper §V.A batching). Raises
        ``KeyError`` on the first missing key — callers fall back per page.
        Returns the stored (immutable, read-only) arrays themselves — no
        defensive copies; published-page immutability makes sharing safe."""
        with self._lock:
            if self.failed:
                raise ProviderFailed(f"data provider {self.provider_id} is down")
            pages = [self._pages[key] for key in page_keys]
            self._serve(len(pages))
            return pages

    def has_page(self, page_key: int) -> bool:
        with self._lock:
            return not self.failed and page_key in self._pages

    def delete_pages(self, page_keys: Sequence[int]) -> None:
        with self._lock:
            for key in page_keys:
                self._pages.pop(key, None)

    @property
    def n_pages(self) -> int:
        with self._lock:
            return len(self._pages)

    def used_bytes(self) -> int:
        with self._lock:
            return sum(p.nbytes for p in self._pages.values())


class ProviderManager:
    """Tracks live data providers and allocates page placements.

    Placement returns, per page, a primary provider and ``replication - 1``
    replica providers (all distinct). The strategy is least-loaded-first over
    a running load counter, which converges to the round-robin-ish balance the
    paper relies on for its throughput scaling.
    """

    def __init__(self, replication: int = 1, stats: Optional[TrafficStats] = None) -> None:
        self.replication = replication
        self._providers: Dict[int, DataProvider] = {}
        self._load: Dict[int, int] = {}
        #: lazy min-heap of (load, provider_id); entries whose load no longer
        #: matches ``_load`` (or whose provider left) are discarded on pop
        self._heap: List[Tuple[int, int]] = []
        #: heap pushes + pops, for complexity assertions in tests
        self.placement_ops = 0
        self._page_key_counter = itertools.count()
        self._lock = make_lock("ProviderManager._lock")
        self.stats = stats or TrafficStats()

    # -- membership (dynamic join/leave, paper §III.A) ---------------------
    def register(self, provider: DataProvider) -> None:
        with self._lock:
            self._providers[provider.provider_id] = provider
            self._load.setdefault(provider.provider_id, 0)
            self._push(provider.provider_id)

    def deregister(self, provider_id: int) -> None:
        with self._lock:
            self._providers.pop(provider_id, None)
            self._load.pop(provider_id, None)
            # heap entries for provider_id go stale and die on pop

    def providers(self) -> List[DataProvider]:
        with self._lock:
            return list(self._providers.values())

    def get_provider(self, provider_id: int) -> DataProvider:
        with self._lock:
            return self._providers[provider_id]

    # -- placement ----------------------------------------------------------
    def _push(self, pid: int) -> None:
        heapq.heappush(self._heap, (self._load[pid], pid))
        self.placement_ops += 1

    def _pop_least_loaded(self, exclude: set) -> int:
        """Pop until a live, non-stale, non-excluded provider surfaces."""
        while True:
            load, pid = heapq.heappop(self._heap)
            self.placement_ops += 1
            if pid not in self._providers or self._load[pid] != load:
                continue  # stale: provider left, or load moved on
            if pid in exclude:
                continue  # duplicate entry of an already-chosen provider
            return pid

    def allocate(self, n_pages: int) -> List[Tuple[PageRef, Tuple[PageRef, ...]]]:
        """Pick (primary, replicas) for ``n_pages`` fresh pages in bulk.

        One lock acquisition and O(n_pages·replication·log P) heap work for
        the whole batch — the per-page sort this replaces was
        O(n_pages·P·log P) *inside the lock*, which serialized concurrent
        writers on placement instead of on the version manager only.
        """
        with self._lock:
            if len(self._providers) < self.replication:
                raise RuntimeError("not enough providers for requested replication")
            out: List[Tuple[PageRef, Tuple[PageRef, ...]]] = []
            for _ in range(n_pages):
                chosen: List[int] = []
                taken: set = set()
                while len(chosen) < self.replication:
                    pid = self._pop_least_loaded(taken)
                    chosen.append(pid)
                    taken.add(pid)
                key = next(self._page_key_counter)
                for pid in chosen:
                    self._load[pid] += 1
                    self._push(pid)
                primary: PageRef = (chosen[0], key)
                replicas: Tuple[PageRef, ...] = tuple((pid, key) for pid in chosen[1:])
                out.append((primary, replicas))
            return out

    def least_loaded(self, exclude: Sequence[int] = ()) -> Optional[int]:
        """Peek the least-loaded live (non-failed) provider not in
        ``exclude`` (for the replica balancer's promotion targets). Returns
        ``None`` if no provider qualifies — one failed cold provider must not
        block promotion while healthy targets exist."""
        excluded = set(exclude)
        with self._lock:
            candidates = [
                pid
                for pid, provider in self._providers.items()
                if pid not in excluded and not provider.failed
            ]
            if not candidates:
                return None
            return min(candidates, key=lambda pid: (self._load[pid], pid))

    def add_load(self, pid: int, n_pages: int = 1) -> None:
        """Charge ``pid`` for pages placed outside :meth:`allocate` (promoted
        hot-page replicas), keeping the heap's least-loaded order truthful."""
        with self._lock:
            if pid in self._load:
                self._load[pid] += n_pages
                self._push(pid)

    def release(self, refs: Sequence[PageRef]) -> None:
        """Return load credit for GC'd pages."""
        with self._lock:
            for pid, _ in refs:
                if pid in self._load and self._load[pid] > 0:
                    self._load[pid] -= 1
                    self._push(pid)

    # -- failure injection ---------------------------------------------------
    # The manager lock only resolves the provider; the flag itself flips
    # under the PROVIDER's lock (set_failed), strictly after the manager lock
    # is released — manager(level 4) -> provider(level 5) nesting is legal but
    # unnecessary here, and the provider lock is what put/get check under.
    def fail_provider(self, provider_id: int) -> None:
        with self._lock:
            provider = self._providers[provider_id]
        provider.set_failed(True)

    def recover_provider(self, provider_id: int) -> None:
        with self._lock:
            provider = self._providers[provider_id]
        provider.set_failed(False)

    def load_snapshot(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._load)
