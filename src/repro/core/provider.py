"""Data providers + provider manager (paper §III.A).

Data providers store pages in RAM. The provider manager tracks registered
providers and, per WRITE, picks which providers receive the freshly written
pages using a load-balancing strategy (least-loaded, ties broken by provider
id — "some strategy that favors global load balancing").

Providers may join and leave dynamically; page replication (``replication``)
plus replica fallback on read provides the fault tolerance the paper defers to
future work.

Placement is a lazy min-heap over ``(load, provider_id)``: allocating a page
pops the ``replication`` least-loaded providers and pushes them back with
their load incremented, so a bulk allocation of ``n`` pages costs
O(n·replication·log P) heap operations instead of the O(n·P·log P) of a
per-page full sort. Stale heap entries (left behind by ``release`` or
membership churn) are discarded on pop; every push/pop is counted in
``placement_ops`` so tests can assert the complexity bound.
"""

from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lockwatch import make_lock

# DEAD/LIVE/SUSPECT and HealthConfig moved to repro.core.dht in the
# metadata-fault PR (both planes share one health machine); imported here so
# existing ``repro.core.provider.HealthConfig`` references keep working.
from repro.core.dht import (  # noqa: F401 - re-exports
    DEAD,
    LIVE,
    SUSPECT,
    HealthConfig,
    ProviderFailed,
    TrafficStats,
)
from repro.core.segment_tree import PageRef


class DataProvider:
    """RAM page store. Pages are immutable once stored (COW discipline).

    All page-map accesses are serialized on a per-provider lock, so concurrent
    ``put_pages``/``delete_pages`` never race ``used_bytes``/``n_pages``
    iterating the dict. ``page_service_seconds`` > 0 models a provider with
    finite service bandwidth: each request holds the lock for that long per
    page transferred (the sleep releases the GIL, so *different* providers
    still serve in parallel — exactly the paper's network model, where a hot
    provider is the bottleneck and spreading load across providers helps).
    """

    def __init__(self, provider_id: int, page_service_seconds: float = 0.0) -> None:
        self.provider_id = provider_id
        self.page_service_seconds = page_service_seconds
        self._pages: Dict[int, np.ndarray] = {}
        self._lock = make_lock("DataProvider._lock")
        self.failed = False
        #: chaos-harness hook (:mod:`repro.core.faults`): called at RPC entry
        #: with ``(op, provider_id)`` BEFORE the provider lock is taken, so an
        #: injector may sleep (delay), raise ``ProviderFailed`` (drop), or
        #: flip failure flags without ever nesting under a level-5 lock
        self.fault_gate: Optional[Callable[[str, int], None]] = None

    def _serve(self, n_pages: int) -> None:
        if self.page_service_seconds > 0.0 and n_pages > 0:
            time.sleep(self.page_service_seconds * n_pages)

    def _gate(self, op: str) -> None:
        gate = self.fault_gate
        if gate is not None:
            gate(op, self.provider_id)

    def set_failed(self, failed: bool) -> None:
        """Flip the failure-injection flag under this provider's own lock, so
        the transition serializes against in-flight ``put_pages``/``get_pages``
        (which check ``failed`` under the same lock): a request observes the
        provider strictly before or strictly after the transition, never a
        torn mid-request flip."""
        with self._lock:
            self.failed = failed

    def put_pages(self, items: Sequence[Tuple[int, np.ndarray]]) -> None:
        """Store pages zero-copy: the given arrays (typically read-only views
        into a writer's frozen source buffer) are referenced, never copied.
        Each stored page is marked read-only here, so the COW discipline is
        enforced at the store boundary no matter what the caller passed."""
        self._gate("put_pages")
        with self._lock:
            if self.failed:
                raise ProviderFailed(f"data provider {self.provider_id} is down")
            for page_key, data in items:
                data.flags.writeable = False
                self._pages[page_key] = data
            self._serve(len(items))

    def get_page(self, page_key: int) -> np.ndarray:
        self._gate("get_page")
        with self._lock:
            if self.failed:
                raise ProviderFailed(f"data provider {self.provider_id} is down")
            page = self._pages[page_key]
            self._serve(1)
            return page

    def get_pages(self, page_keys: Sequence[int]) -> List[np.ndarray]:
        """One aggregated RPC for many pages (paper §V.A batching). Raises
        ``KeyError`` on the first missing key — callers fall back per page.
        Returns the stored (immutable, read-only) arrays themselves — no
        defensive copies; published-page immutability makes sharing safe."""
        self._gate("get_pages")
        with self._lock:
            if self.failed:
                raise ProviderFailed(f"data provider {self.provider_id} is down")
            pages = [self._pages[key] for key in page_keys]
            self._serve(len(pages))
            return pages

    def has_page(self, page_key: int) -> bool:
        with self._lock:
            return not self.failed and page_key in self._pages

    def delete_pages(self, page_keys: Sequence[int]) -> None:
        with self._lock:
            for key in page_keys:
                self._pages.pop(key, None)

    @property
    def n_pages(self) -> int:
        with self._lock:
            return len(self._pages)

    def used_bytes(self) -> int:
        with self._lock:
            return sum(p.nbytes for p in self._pages.values())


class ProviderManager:
    """Tracks live data providers and allocates page placements.

    Placement returns, per page, a primary provider and ``replication - 1``
    replica providers (all distinct). The strategy is least-loaded-first over
    a running load counter, which converges to the round-robin-ish balance the
    paper relies on for its throughput scaling.
    """

    def __init__(
        self,
        replication: int = 1,
        stats: Optional[TrafficStats] = None,
        health: Optional[HealthConfig] = None,
    ) -> None:
        self.replication = replication
        self._providers: Dict[int, DataProvider] = {}
        self._load: Dict[int, int] = {}
        #: lazy min-heap of (load, provider_id); entries whose load no longer
        #: matches ``_load`` (or whose provider left) are discarded on pop
        self._heap: List[Tuple[int, int]] = []
        #: heap pushes + pops, for complexity assertions in tests
        self.placement_ops = 0
        self._page_key_counter = itertools.count()
        self._lock = make_lock("ProviderManager._lock")
        self.stats = stats or TrafficStats()
        self.health_config = health or HealthConfig()
        #: per-provider failure timestamps within the decay window; a pid is
        #: present here only while it has recorded failures, so the hot-path
        #: ``note_success`` membership probe stays a racy dict lookup
        self._failures: Dict[int, List[float]] = {}
        #: pids declared dead (sticky until success/recover)
        self._dead: set = set()
        #: invoked OUTSIDE the manager lock when a provider transitions to
        #: dead — the cluster wires this to RepairService scheduling
        self.on_dead: Optional[Callable[[int], None]] = None

    # -- membership (dynamic join/leave, paper §III.A) ---------------------
    def register(self, provider: DataProvider) -> None:
        with self._lock:
            self._providers[provider.provider_id] = provider
            self._load.setdefault(provider.provider_id, 0)
            self._push(provider.provider_id)

    def deregister(self, provider_id: int) -> int:
        """Remove a provider and release its outstanding load credit.

        Returns the released credit (pages the manager still charged to the
        provider when it left) so callers can account for the re-placement
        work the departure implies. Health records go with it — a provider
        that re-registers under the same id starts live with zero load.
        """
        with self._lock:
            self._providers.pop(provider_id, None)
            credit = self._load.pop(provider_id, 0)
            self._failures.pop(provider_id, None)
            self._dead.discard(provider_id)
            # heap entries for provider_id go stale and die on pop
            return credit

    def providers(self) -> List[DataProvider]:
        with self._lock:
            return list(self._providers.values())

    def get_provider(self, provider_id: int) -> DataProvider:
        with self._lock:
            return self._resolve_locked(provider_id)

    def _resolve_locked(self, provider_id: int) -> DataProvider:
        try:
            return self._providers[provider_id]
        except KeyError:
            raise KeyError(
                f"unknown data provider id {provider_id}; registered ids: "
                f"{sorted(self._providers)}"
            ) from None

    # -- health (live -> suspect -> dead, paper-deferred fault tolerance) ----
    def note_failure(self, provider_id: int) -> None:
        """Record an observed RPC failure against ``provider_id``.

        Transitions the provider ``live -> suspect -> dead`` per the
        :class:`HealthConfig` thresholds. The ``on_dead`` callback fires
        exactly once per death, outside the manager lock (it schedules
        repair work that takes other level-4 locks).
        """
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        newly_dead = False
        with self._lock:
            if provider_id not in self._providers:
                return  # departed or never registered: nothing to track
            record = self._failures.setdefault(provider_id, [])
            record.append(now)
            while record and record[0] < horizon:
                record.pop(0)
            if (
                len(record) >= self.health_config.dead_after
                and provider_id not in self._dead
            ):
                self._dead.add(provider_id)
                newly_dead = True
            callback = self.on_dead
        if newly_dead and callback is not None:
            callback(provider_id)

    def note_success(self, provider_id: int) -> None:
        """An observed successful RPC clears suspicion and death. The
        unlocked membership probe keeps this free on the (overwhelmingly
        common) healthy fast path; the race is benign — a concurrent
        ``note_failure`` simply wins or loses the lock like any other
        interleaving of the two observations."""
        if provider_id not in self._failures and provider_id not in self._dead:
            return
        with self._lock:
            self._failures.pop(provider_id, None)
            if provider_id in self._dead:
                self._dead.discard(provider_id)
                if provider_id in self._load:
                    self._push(provider_id)

    def health_state(self, provider_id: int) -> str:
        """``live``/``suspect``/``dead`` for a registered provider."""
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        with self._lock:
            self._resolve_locked(provider_id)
            return self._health_state_locked(provider_id, horizon)

    def _health_state_locked(self, provider_id: int, horizon: float) -> str:
        if provider_id in self._dead:
            return DEAD
        record = self._failures.get(provider_id)
        if not record:
            return LIVE
        recent = sum(1 for t in record if t >= horizon)
        return SUSPECT if recent >= self.health_config.suspect_after else LIVE

    def healthy_providers(self) -> List[DataProvider]:
        """Providers currently ``live`` (no recent failures, not failed) —
        the candidate set for repair targets and fresh placements."""
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        with self._lock:
            return [
                provider
                for pid, provider in self._providers.items()
                if not provider.failed
                and self._health_state_locked(pid, horizon) == LIVE
            ]

    def dead_providers(self) -> List[int]:
        """Pids currently declared dead (repair's work queue)."""
        with self._lock:
            return sorted(self._dead)

    def _placeable_locked(self, pid: int) -> bool:
        """Placement admits live and suspect providers but never dead or
        failure-flagged ones: one blip should not evict a node from
        placement (the retry layer absorbs it), a declared death must."""
        provider = self._providers.get(pid)
        return provider is not None and not provider.failed and pid not in self._dead

    # -- placement ----------------------------------------------------------
    def _push(self, pid: int) -> None:
        heapq.heappush(self._heap, (self._load[pid], pid))
        self.placement_ops += 1

    def _pop_least_loaded(self, exclude: set, stash: List[Tuple[int, int]]) -> int:
        """Pop until a healthy, non-stale, non-excluded provider surfaces.

        Valid heap entries of *unhealthy* (failed or dead) providers are
        stashed instead of discarded — the caller re-pushes them after the
        batch, so a provider that later recovers resurfaces in the heap
        without any re-seeding bookkeeping.
        """
        while True:
            try:
                load, pid = heapq.heappop(self._heap)
            except IndexError:
                raise ProviderFailed(
                    "placement heap exhausted: no healthy provider available"
                ) from None
            self.placement_ops += 1
            if pid not in self._providers or self._load[pid] != load:
                continue  # stale: provider left, or load moved on
            if pid in exclude:
                continue  # duplicate entry of an already-chosen provider
            if not self._placeable_locked(pid):
                stash.append((load, pid))  # valid entry, provider down: keep
                continue
            return pid

    def allocate(self, n_pages: int) -> List[Tuple[PageRef, Tuple[PageRef, ...]]]:
        """Pick (primary, replicas) for ``n_pages`` fresh pages in bulk.

        One lock acquisition and O(n_pages·replication·log P) heap work for
        the whole batch — the per-page sort this replaces was
        O(n_pages·P·log P) *inside the lock*, which serialized concurrent
        writers on placement instead of on the version manager only.

        Only *healthy* providers (not failure-flagged, not declared dead)
        receive placements; raises ``RuntimeError`` when fewer than
        ``replication`` of them remain.
        """
        with self._lock:
            placeable = sum(1 for pid in self._providers if self._placeable_locked(pid))
            if placeable < self.replication:
                # ProviderFailed (a RuntimeError) rather than a bare
                # RuntimeError: writers treat "no healthy placement" exactly
                # like a provider failure — abort, abandon, clean up
                raise ProviderFailed(
                    f"only {placeable} healthy providers for replication "
                    f"{self.replication} ({len(self._providers)} registered)"
                )
            stash: List[Tuple[int, int]] = []
            out: List[Tuple[PageRef, Tuple[PageRef, ...]]] = []
            for _ in range(n_pages):
                chosen: List[int] = []
                taken: set = set()
                while len(chosen) < self.replication:
                    pid = self._pop_least_loaded(taken, stash)
                    chosen.append(pid)
                    taken.add(pid)
                key = next(self._page_key_counter)
                for pid in chosen:
                    self._load[pid] += 1
                    self._push(pid)
                primary: PageRef = (chosen[0], key)
                replicas: Tuple[PageRef, ...] = tuple((pid, key) for pid in chosen[1:])
                out.append((primary, replicas))
            for entry in stash:  # down providers stay discoverable post-recovery
                heapq.heappush(self._heap, entry)
                self.placement_ops += 1
            return out

    def least_loaded(self, exclude: Sequence[int] = ()) -> Optional[int]:
        """Peek the least-loaded healthy (non-failed, non-dead) provider not
        in ``exclude`` (for the replica balancer's promotion targets and the
        write plane's mid-flight re-placements). Returns ``None`` if no
        provider qualifies — one failed cold provider must not block
        promotion while healthy targets exist."""
        excluded = set(exclude)
        with self._lock:
            candidates = [
                pid
                for pid in self._providers
                if pid not in excluded and self._placeable_locked(pid)
            ]
            if not candidates:
                return None
            return min(candidates, key=lambda pid: (self._load[pid], pid))

    def add_load(self, pid: int, n_pages: int = 1) -> None:
        """Charge ``pid`` for pages placed outside :meth:`allocate` (promoted
        hot-page replicas), keeping the heap's least-loaded order truthful."""
        with self._lock:
            if pid in self._load:
                self._load[pid] += n_pages
                self._push(pid)

    def release(self, refs: Sequence[PageRef]) -> None:
        """Return load credit for GC'd pages."""
        with self._lock:
            for pid, _ in refs:
                if pid in self._load and self._load[pid] > 0:
                    self._load[pid] -= 1
                    self._push(pid)

    # -- failure injection ---------------------------------------------------
    # The manager lock only resolves the provider; the flag itself flips
    # under the PROVIDER's lock (set_failed), strictly after the manager lock
    # is released — manager(level 4) -> provider(level 5) nesting is legal but
    # unnecessary here, and the provider lock is what put/get check under.
    def fail_provider(self, provider_id: int) -> None:
        with self._lock:
            provider = self._resolve_locked(provider_id)
        provider.set_failed(True)

    def recover_provider(self, provider_id: int) -> None:
        """Clear the failure-injection flag AND the health record — this is
        the provider's rejoin announcement, so it comes back ``live`` and
        placeable immediately."""
        with self._lock:
            provider = self._resolve_locked(provider_id)
            self._failures.pop(provider_id, None)
            self._dead.discard(provider_id)
            self._push(provider_id)  # guarantee a fresh, valid heap entry
        provider.set_failed(False)

    def load_snapshot(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._load)
