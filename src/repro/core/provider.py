"""Data providers + provider manager (paper §III.A).

Data providers store pages in RAM. The provider manager tracks registered
providers and, per WRITE, picks which providers receive the freshly written
pages using a load-balancing strategy (least-loaded, ties broken round-robin
— "some strategy that favors global load balancing").

Providers may join and leave dynamically; page replication (``replication``)
plus replica fallback on read provides the fault tolerance the paper defers to
future work.
"""

from __future__ import annotations

import itertools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dht import ProviderFailed, TrafficStats
from repro.core.segment_tree import PageRef


class DataProvider:
    """RAM page store. Pages are immutable once stored (COW discipline)."""

    def __init__(self, provider_id: int) -> None:
        self.provider_id = provider_id
        self._pages: Dict[int, np.ndarray] = {}
        self.failed = False

    def put_pages(self, items: Sequence[Tuple[int, np.ndarray]]) -> None:
        if self.failed:
            raise ProviderFailed(f"data provider {self.provider_id} is down")
        for page_key, data in items:
            self._pages[page_key] = data

    def get_page(self, page_key: int) -> np.ndarray:
        if self.failed:
            raise ProviderFailed(f"data provider {self.provider_id} is down")
        return self._pages[page_key]

    def get_pages(self, page_keys: Sequence[int]) -> List[np.ndarray]:
        """One aggregated RPC for many pages (paper §V.A batching). Raises
        ``KeyError`` on the first missing key — callers fall back per page."""
        if self.failed:
            raise ProviderFailed(f"data provider {self.provider_id} is down")
        return [self._pages[key] for key in page_keys]

    def delete_pages(self, page_keys: Sequence[int]) -> None:
        for key in page_keys:
            self._pages.pop(key, None)

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    def used_bytes(self) -> int:
        return sum(p.nbytes for p in self._pages.values())


class ProviderManager:
    """Tracks live data providers and allocates page placements.

    Placement returns, per page, a primary provider and ``replication - 1``
    replica providers (all distinct). The strategy is least-loaded-first over
    a running load counter, which converges to the round-robin-ish balance the
    paper relies on for its throughput scaling.
    """

    def __init__(self, replication: int = 1, stats: Optional[TrafficStats] = None) -> None:
        self.replication = replication
        self._providers: Dict[int, DataProvider] = {}
        self._load: Dict[int, int] = {}
        self._page_key_counter = itertools.count()
        self._lock = threading.Lock()
        self.stats = stats or TrafficStats()

    # -- membership (dynamic join/leave, paper §III.A) ---------------------
    def register(self, provider: DataProvider) -> None:
        with self._lock:
            self._providers[provider.provider_id] = provider
            self._load.setdefault(provider.provider_id, 0)

    def deregister(self, provider_id: int) -> None:
        with self._lock:
            self._providers.pop(provider_id, None)
            self._load.pop(provider_id, None)

    def providers(self) -> List[DataProvider]:
        with self._lock:
            return list(self._providers.values())

    def get_provider(self, provider_id: int) -> DataProvider:
        with self._lock:
            return self._providers[provider_id]

    # -- placement ----------------------------------------------------------
    def allocate(self, n_pages: int) -> List[Tuple[PageRef, Tuple[PageRef, ...]]]:
        """Pick (primary, replicas) for ``n_pages`` fresh pages."""
        with self._lock:
            if len(self._providers) < self.replication:
                raise RuntimeError("not enough providers for requested replication")
            out: List[Tuple[PageRef, Tuple[PageRef, ...]]] = []
            for _ in range(n_pages):
                ranked = sorted(self._load, key=lambda pid: (self._load[pid], pid))
                chosen = ranked[: self.replication]
                key = next(self._page_key_counter)
                for pid in chosen:
                    self._load[pid] += 1
                primary: PageRef = (chosen[0], key)
                replicas: Tuple[PageRef, ...] = tuple((pid, key) for pid in chosen[1:])
                out.append((primary, replicas))
            return out

    def release(self, refs: Sequence[PageRef]) -> None:
        """Return load credit for GC'd pages."""
        with self._lock:
            for pid, _ in refs:
                if pid in self._load and self._load[pid] > 0:
                    self._load[pid] -= 1

    # -- failure injection ---------------------------------------------------
    def fail_provider(self, provider_id: int) -> None:
        self._providers[provider_id].failed = True

    def recover_provider(self, provider_id: int) -> None:
        self._providers[provider_id].failed = False

    def load_snapshot(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._load)
