"""Deterministic chaos harness: seeded fault schedules for all three planes.

The self-healing machinery (health states, retry/backoff, mid-flight write
re-placement, replica-fallback reads, background repair) is only as
trustworthy as the failures it was exercised under. This module injects
those failures *deterministically*:

* A :class:`FaultSchedule` is a seeded, immutable list of
  :class:`FaultEvent`\\ s positioned in **operation space** — "at the N-th
  data-plane RPC, kill provider 3" — not wall-clock time, so a loaded CI
  machine and a laptop replay the same fault sequence.
* A :class:`FaultInjector` attaches to every data provider's AND metadata
  shard's ``fault_gate`` (an RPC-entry hook that runs BEFORE the actor's
  lock) and counts RPCs cluster-wide on one shared clock; events fire as
  their op index is crossed. Kills flip the actor's failure flag
  (``ProviderManager.fail_provider`` / ``MetadataDHT.fail_shard``) —
  in-flight requests observe the flip exactly as a real crash: mid-batch,
  under live traffic. Drops fail one single RPC; delays stall one RPC.
* A third, **node plane** (``target="node"``) drives whole federation
  nodes on the same shared op clock: ``kill`` / ``wedge`` down a node,
  ``partition`` cuts only its GC-coordinator RPCs (data plane intact — the
  lease-fencing story), ``recover`` rejoins it at the current epoch. Node
  events need a :class:`~repro.core.federation.Federation` as the
  injector's cluster.

Determinism caveat, stated honestly: the *schedule* is deterministic, but
which concurrent client's RPC crosses the op threshold depends on thread
interleaving. Chaos tests therefore assert interleaving-independent
invariants (zero published-data loss, monotone publish frontier,
replication-factor restoration) rather than exact traces — the properties
the paper's lock-free design must hold under ANY interleaving.

All injector state lives under its own level-3 lock; fault ACTIONS
(kill/recover/sleep/raise) run strictly outside it, so the gate never nests
into the manager or provider locks while holding anything.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import TYPE_CHECKING, Dict, List, Sequence, Set, Tuple

from repro.analysis.lockwatch import make_lock
from repro.core.dht import ProviderFailed

if TYPE_CHECKING:  # pragma: no cover - cluster imports stay one-directional
    from repro.core.cluster import Cluster

#: fault actions
KILL = "kill"  #: flip the provider's failed flag (stays down until recover)
RECOVER = "recover"  #: clear the flag + health record (rejoin announcement)
DROP = "drop"  #: fail exactly one subsequent RPC at the provider
DELAY = "delay"  #: stall exactly one subsequent RPC by ``param`` seconds
#: node-plane only: cut the node's GC-coordinator RPCs, data plane intact —
#: exercises the lease-fencing story rather than plain unavailability
PARTITION = "partition"
#: node-plane only: the node hangs — every data op raises, process "alive"
WEDGE = "wedge"

#: fault targets — which plane's RPCs the event hits
DATA = "data"  #: ``provider_id`` names a data provider
METADATA = "metadata"  #: ``provider_id`` names a metadata shard
#: ``provider_id`` names a federation node (requires a
#: :class:`~repro.core.federation.Federation` as the injector's cluster)
NODE = "node"


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One scheduled fault: at the ``at_op``-th cluster-wide RPC (or
    later — the next RPC to cross the threshold), apply ``action`` to
    ``provider_id``. ``param`` is the delay in seconds for ``delay``;
    ``target`` selects the plane (``provider_id`` is a data provider id for
    :data:`DATA`, a metadata shard id for :data:`METADATA`). Both planes
    advance the SAME op clock, so a mixed campaign interleaves its kills
    exactly where the merged traffic crossed each threshold."""

    at_op: int
    action: str
    provider_id: int
    param: float = 0.0
    target: str = DATA


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, op-ordered fault sequence. Build directly from events
    or via :meth:`generate` for a seeded random campaign."""

    events: Sequence[FaultEvent] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(sorted(self.events)))

    @classmethod
    def generate(
        cls,
        seed: int,
        n_providers: int,
        n_events: int = 12,
        max_dead: int = 1,
        min_gap: int = 5,
        max_gap: int = 40,
        delay_seconds: float = 0.002,
        recover_all: bool = True,
        target: str = DATA,
    ) -> "FaultSchedule":
        """Seeded random campaign: kills, recoveries, drops and delays, with
        at most ``max_dead`` providers down simultaneously (the chaos tests
        pair this with replication > max_dead so published data must
        survive). With ``recover_all`` every still-dead provider gets a
        trailing recover event, so repair can restore full replication.
        ``target`` aims the whole campaign at one plane; merge two campaigns
        with ``FaultSchedule(a.events + b.events)`` for mixed chaos."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        dead: Set[int] = set()
        op = 0
        for _ in range(n_events):
            op += rng.randint(min_gap, max_gap)
            roll = rng.random()
            alive = [p for p in range(n_providers) if p not in dead]
            if target == NODE:
                # node plane: kill / partition / wedge / rejoin — no
                # one-shot drops/delays (those belong to the RPC planes)
                if dead and roll < 0.4:
                    pid = rng.choice(sorted(dead))
                    dead.discard(pid)
                    events.append(FaultEvent(op, RECOVER, pid, target=NODE))
                elif len(dead) < max_dead and alive:
                    pid = rng.choice(alive)
                    dead.add(pid)
                    action = (KILL, PARTITION, WEDGE)[rng.randint(0, 2)]
                    events.append(FaultEvent(op, action, pid, target=NODE))
                continue
            if dead and roll < 0.25:
                pid = rng.choice(sorted(dead))
                dead.discard(pid)
                events.append(FaultEvent(op, RECOVER, pid, target=target))
            elif len(dead) < max_dead and roll < 0.55 and alive:
                pid = rng.choice(alive)
                dead.add(pid)
                events.append(FaultEvent(op, KILL, pid, target=target))
            elif roll < 0.8 and alive:
                events.append(
                    FaultEvent(op, DROP, rng.choice(alive), target=target)
                )
            elif alive:
                events.append(
                    FaultEvent(
                        op, DELAY, rng.choice(alive), delay_seconds, target
                    )
                )
        if recover_all:
            for pid in sorted(dead):
                op += rng.randint(min_gap, max_gap)
                events.append(FaultEvent(op, RECOVER, pid, target=target))
        return cls(tuple(events))


class FaultInjector:
    """Drives a :class:`FaultSchedule` against a live cluster.

    Usage::

        injector = FaultInjector(cluster, schedule)
        injector.attach()
        try:
            ...  # run traffic; faults fire as RPCs cross the op thresholds
            injector.drain()  # force any not-yet-reached kills/recovers
        finally:
            injector.detach()
    """

    def __init__(self, cluster: "Cluster", schedule: FaultSchedule) -> None:
        self.cluster = cluster
        self.schedule = schedule
        self._lock = make_lock("FaultInjector._lock")
        self._op = 0
        self._pending: List[FaultEvent] = list(schedule.events)
        #: per-(target, id) one-shot faults armed by DROP/DELAY events
        self._drops: Dict[Tuple[str, int], int] = {}
        self._delays: Dict[Tuple[str, int], float] = {}
        #: applied events, for test introspection
        self.fired: List[FaultEvent] = []

    # -- wiring ---------------------------------------------------------------
    def attach(self) -> None:
        for provider in self.cluster.provider_manager.providers():
            provider.fault_gate = self._gate
        for shard in self.cluster.metadata.shards:
            shard.fault_gate = self._meta_gate

    def detach(self) -> None:
        for provider in self.cluster.provider_manager.providers():
            provider.fault_gate = None
        for shard in self.cluster.metadata.shards:
            shard.fault_gate = None

    # -- the gates ------------------------------------------------------------
    def _gate(self, op: str, provider_id: int) -> None:
        """Data-plane RPC-entry hook (runs lock-free in the provider, before
        its own lock)."""
        self._gate_common(op, provider_id, DATA)

    def _meta_gate(self, op: str, shard_id: int) -> None:
        """Metadata-plane RPC-entry hook: same op clock as the data gate, so
        one schedule interleaves faults across both planes."""
        self._gate_common(op, shard_id, METADATA)

    def _gate_common(self, op: str, actor_id: int, target: str) -> None:
        """Advance the shared op clock, apply due events, then enforce any
        one-shot drop/delay armed for this (plane, actor)."""
        due: List[FaultEvent] = []
        key = (target, actor_id)
        with self._lock:
            self._op += 1
            while self._pending and self._pending[0].at_op <= self._op:
                due.append(self._pending.pop(0))
        for event in due:
            self._apply(event)
        # consume one-shots AFTER applying due events, so a drop/delay whose
        # op threshold this very RPC crossed hits this RPC, not the next one
        with self._lock:
            delay = self._delays.pop(key, 0.0)
            dropped = self._drops.get(key, 0)
            if dropped:
                self._drops[key] = dropped - 1
        if delay > 0.0:
            time.sleep(delay)  # outside every lock: stalls only this RPC
        if dropped:
            raise ProviderFailed(
                f"injected drop: {target} actor {actor_id} {op} RPC"
            )

    def _apply(self, event: FaultEvent) -> None:
        try:
            if event.action == KILL:
                self._kill(event)
            elif event.action == RECOVER:
                self._recover(event)
            elif event.action in (PARTITION, WEDGE):
                self._node_fault(event)
            elif event.action == DROP:
                with self._lock:
                    key = (event.target, event.provider_id)
                    self._drops[key] = self._drops.get(key, 0) + 1
            elif event.action == DELAY:
                with self._lock:
                    self._delays[(event.target, event.provider_id)] = (
                        event.param
                    )
            else:
                raise ValueError(f"unknown fault action {event.action!r}")
        except KeyError:
            pass  # provider deregistered mid-campaign: fault is moot
        with self._lock:
            self.fired.append(event)

    def _kill(self, event: FaultEvent) -> None:
        if event.target == NODE:
            self._node_fault(event)
        elif event.target == METADATA:
            self.cluster.metadata.fail_shard(event.provider_id)
        else:
            self.cluster.provider_manager.fail_provider(event.provider_id)

    def _recover(self, event: FaultEvent) -> None:
        if event.target == NODE:
            self._node_fault(event)
        elif event.target == METADATA:
            self.cluster.metadata.recover_shard(event.provider_id)
        else:
            self.cluster.provider_manager.recover_provider(event.provider_id)

    def _node_fault(self, event: FaultEvent) -> None:
        """Node-plane dispatch: the injector's ``cluster`` must be a
        :class:`~repro.core.federation.Federation` (it quacks like a cluster
        for the RPC planes — ``provider_manager`` + ``metadata`` — and adds
        ``apply_node_fault`` for this one)."""
        apply = getattr(self.cluster, "apply_node_fault", None)
        if apply is None:
            raise ValueError(
                "node-plane fault events require a Federation, "
                f"got {type(self.cluster).__name__}"
            )
        apply(event.provider_id, event.action)

    # -- campaign control -----------------------------------------------------
    def drain(self) -> None:
        """Apply every not-yet-fired kill/recover immediately (traffic ended
        before the op clock reached them). One-shot drops/delays are
        discarded — there is no RPC left for them to hit."""
        with self._lock:
            pending, self._pending = self._pending, []
            self._drops.clear()
            self._delays.clear()
        for event in pending:
            if event.action in (KILL, RECOVER, PARTITION, WEDGE):
                self._apply(event)

    def ops_seen(self) -> int:
        with self._lock:
            return self._op

    def pending_events(self) -> List[FaultEvent]:
        with self._lock:
            return list(self._pending)
