"""Deterministic chaos harness: seeded fault schedules for the data plane.

The self-healing machinery (health states, retry/backoff, mid-flight write
re-placement, replica-fallback reads, background repair) is only as
trustworthy as the failures it was exercised under. This module injects
those failures *deterministically*:

* A :class:`FaultSchedule` is a seeded, immutable list of
  :class:`FaultEvent`\\ s positioned in **operation space** — "at the N-th
  data-plane RPC, kill provider 3" — not wall-clock time, so a loaded CI
  machine and a laptop replay the same fault sequence.
* A :class:`FaultInjector` attaches to every provider's ``fault_gate`` (an
  RPC-entry hook that runs BEFORE the provider's lock) and counts RPCs
  cluster-wide; events fire as their op index is crossed. Kills flip the
  provider's failure flag through ``ProviderManager.fail_provider`` —
  in-flight requests observe the flip exactly as a real crash: mid-batch,
  under live traffic. Drops fail one single RPC; delays stall one RPC.

Determinism caveat, stated honestly: the *schedule* is deterministic, but
which concurrent client's RPC crosses the op threshold depends on thread
interleaving. Chaos tests therefore assert interleaving-independent
invariants (zero published-data loss, monotone publish frontier,
replication-factor restoration) rather than exact traces — the properties
the paper's lock-free design must hold under ANY interleaving.

All injector state lives under its own level-3 lock; fault ACTIONS
(kill/recover/sleep/raise) run strictly outside it, so the gate never nests
into the manager or provider locks while holding anything.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import TYPE_CHECKING, Dict, List, Sequence, Set

from repro.analysis.lockwatch import make_lock
from repro.core.dht import ProviderFailed

if TYPE_CHECKING:  # pragma: no cover - cluster imports stay one-directional
    from repro.core.cluster import Cluster

#: fault actions
KILL = "kill"  #: flip the provider's failed flag (stays down until recover)
RECOVER = "recover"  #: clear the flag + health record (rejoin announcement)
DROP = "drop"  #: fail exactly one subsequent RPC at the provider
DELAY = "delay"  #: stall exactly one subsequent RPC by ``param`` seconds


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One scheduled fault: at the ``at_op``-th cluster-wide data RPC (or
    later — the next RPC to cross the threshold), apply ``action`` to
    ``provider_id``. ``param`` is the delay in seconds for ``delay``."""

    at_op: int
    action: str
    provider_id: int
    param: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, op-ordered fault sequence. Build directly from events
    or via :meth:`generate` for a seeded random campaign."""

    events: Sequence[FaultEvent] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(sorted(self.events)))

    @classmethod
    def generate(
        cls,
        seed: int,
        n_providers: int,
        n_events: int = 12,
        max_dead: int = 1,
        min_gap: int = 5,
        max_gap: int = 40,
        delay_seconds: float = 0.002,
        recover_all: bool = True,
    ) -> "FaultSchedule":
        """Seeded random campaign: kills, recoveries, drops and delays, with
        at most ``max_dead`` providers down simultaneously (the chaos tests
        pair this with replication > max_dead so published data must
        survive). With ``recover_all`` every still-dead provider gets a
        trailing recover event, so repair can restore full replication."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        dead: Set[int] = set()
        op = 0
        for _ in range(n_events):
            op += rng.randint(min_gap, max_gap)
            roll = rng.random()
            alive = [p for p in range(n_providers) if p not in dead]
            if dead and roll < 0.25:
                pid = rng.choice(sorted(dead))
                dead.discard(pid)
                events.append(FaultEvent(op, RECOVER, pid))
            elif len(dead) < max_dead and roll < 0.55 and alive:
                pid = rng.choice(alive)
                dead.add(pid)
                events.append(FaultEvent(op, KILL, pid))
            elif roll < 0.8 and alive:
                events.append(FaultEvent(op, DROP, rng.choice(alive)))
            elif alive:
                events.append(
                    FaultEvent(op, DELAY, rng.choice(alive), delay_seconds)
                )
        if recover_all:
            for pid in sorted(dead):
                op += rng.randint(min_gap, max_gap)
                events.append(FaultEvent(op, RECOVER, pid))
        return cls(tuple(events))


class FaultInjector:
    """Drives a :class:`FaultSchedule` against a live cluster.

    Usage::

        injector = FaultInjector(cluster, schedule)
        injector.attach()
        try:
            ...  # run traffic; faults fire as RPCs cross the op thresholds
            injector.drain()  # force any not-yet-reached kills/recovers
        finally:
            injector.detach()
    """

    def __init__(self, cluster: "Cluster", schedule: FaultSchedule) -> None:
        self.cluster = cluster
        self.schedule = schedule
        self._lock = make_lock("FaultInjector._lock")
        self._op = 0
        self._pending: List[FaultEvent] = list(schedule.events)
        #: per-provider one-shot faults armed by DROP/DELAY events
        self._drops: Dict[int, int] = {}
        self._delays: Dict[int, float] = {}
        #: applied events, for test introspection
        self.fired: List[FaultEvent] = []

    # -- wiring ---------------------------------------------------------------
    def attach(self) -> None:
        for provider in self.cluster.provider_manager.providers():
            provider.fault_gate = self._gate

    def detach(self) -> None:
        for provider in self.cluster.provider_manager.providers():
            provider.fault_gate = None

    # -- the gate -------------------------------------------------------------
    def _gate(self, op: str, provider_id: int) -> None:
        """RPC-entry hook (runs lock-free in the provider, before its own
        lock): advance the op clock, apply due events, then enforce any
        one-shot drop/delay armed for this provider."""
        due: List[FaultEvent] = []
        with self._lock:
            self._op += 1
            while self._pending and self._pending[0].at_op <= self._op:
                due.append(self._pending.pop(0))
        for event in due:
            self._apply(event)
        # consume one-shots AFTER applying due events, so a drop/delay whose
        # op threshold this very RPC crossed hits this RPC, not the next one
        with self._lock:
            delay = self._delays.pop(provider_id, 0.0)
            dropped = self._drops.get(provider_id, 0)
            if dropped:
                self._drops[provider_id] = dropped - 1
        if delay > 0.0:
            time.sleep(delay)  # outside every lock: stalls only this RPC
        if dropped:
            raise ProviderFailed(
                f"injected drop: provider {provider_id} {op} RPC"
            )

    def _apply(self, event: FaultEvent) -> None:
        pm = self.cluster.provider_manager
        try:
            if event.action == KILL:
                pm.fail_provider(event.provider_id)
            elif event.action == RECOVER:
                pm.recover_provider(event.provider_id)
            elif event.action == DROP:
                with self._lock:
                    self._drops[event.provider_id] = (
                        self._drops.get(event.provider_id, 0) + 1
                    )
            elif event.action == DELAY:
                with self._lock:
                    self._delays[event.provider_id] = event.param
            else:
                raise ValueError(f"unknown fault action {event.action!r}")
        except KeyError:
            pass  # provider deregistered mid-campaign: fault is moot
        with self._lock:
            self.fired.append(event)

    # -- campaign control -----------------------------------------------------
    def drain(self) -> None:
        """Apply every not-yet-fired kill/recover immediately (traffic ended
        before the op clock reached them). One-shot drops/delays are
        discarded — there is no RPC left for them to hit."""
        with self._lock:
            pending, self._pending = self._pending, []
            self._drops.clear()
            self._delays.clear()
        for event in pending:
            if event.action in (KILL, RECOVER):
                self._apply(event)

    def ops_seen(self) -> int:
        with self._lock:
            return self._op

    def pending_events(self) -> List[FaultEvent]:
        with self._lock:
            return list(self._pending)
