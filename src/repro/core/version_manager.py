"""Version manager (paper §III.A/§IV): the system's only serialization point.

Responsibilities, exactly as in the paper:

* assign monotonically increasing version numbers to WRITEs of a blob;
* **precompute border-node links** for each assigned version from the interval
  history of *all* previously assigned versions — published or not — so that
  concurrent writers weave their metadata trees in complete isolation
  (paper §IV.C);
* publish versions **in order**: version ``v`` becomes visible to readers only
  once versions ``1..v`` have all reported success. This yields the paper's
  global serializability (every READ of version ``v`` sees exactly the first
  ``v`` patches) and liveness (every WRITE eventually publishes).

Fault tolerance (paper's future work, implemented here): every state
transition is appended to a journal; :func:`VersionManager.recover` rebuilds a
manager from a journal replay, and unfinished assignments are surfaced so the
caller can retry or abandon them. :meth:`VersionManager.abandon` is the online
analog — a writer whose data or metadata puts failed mid-flight withdraws its
assigned versions so in-order publication is never wedged behind a version
that will never report success.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lockwatch import make_condition, make_lock
from repro.core.segment_tree import BorderLink, ZERO_VERSION, compute_border_links


class VersionAbandoned(ValueError):
    """The awaited version was withdrawn by a failed writer — it will never
    publish as written. Raised by :meth:`VersionManager.wait_published` so a
    waiter fails fast the moment :meth:`VersionManager.abandon` runs, instead
    of blocking for its full timeout on a version that cannot arrive."""


@dataclasses.dataclass
class JournalEntry:
    op: str  # "alloc" | "assign" | "complete" | "abandon"
    blob_id: int
    version: int = 0
    offset: int = 0
    size: int = 0
    total_pages: int = 0
    page_size: int = 0


@dataclasses.dataclass
class _BlobState:
    total_pages: int
    page_size: int
    #: latest assigned version (may exceed latest published under concurrency)
    assigned: int = 0
    #: latest published version; versions publish strictly in order
    published: int = 0
    #: interval history: version -> (offset, size) in pages
    intervals: Dict[int, Tuple[int, int]] = dataclasses.field(default_factory=dict)
    #: versions that reported success but are not yet publishable
    completed: set = dataclasses.field(default_factory=set)
    #: versions withdrawn by failed writers; publication skips over them but
    #: they are never readable (their trees were never fully stored)
    aborted: set = dataclasses.field(default_factory=set)
    #: immutable snapshot of ``aborted``, swapped (never mutated) under the
    #: manager lock — read paths grab it lock-free to decide whether the
    #: aborted-link redirect machinery needs to engage at all
    aborted_view: frozenset = frozenset()
    #: versions fully *erased* by abandon (they were the latest assignment, so
    #: interval history rolled back) and not yet reassigned to a new writer.
    #: Publication can never reach them until reassignment, so waiters treat
    #: them exactly like aborted holes and fail fast; ``assign_versions``
    #: clears a number from here the moment a new writer takes it.
    withdrawn: set = dataclasses.field(default_factory=set)
    #: per-page latest assigned version, for O(range-max) border queries
    page_versions: Optional[np.ndarray] = None


class VersionManager:
    """Serializes version assignment; everything else stays parallel."""

    def __init__(self) -> None:
        self._blobs: Dict[int, _BlobState] = {}
        self._blob_id_counter = 0
        self._lock = make_lock("VersionManager._lock")
        self._published_cv = make_condition(
            "VersionManager._published_cv", lock=self._lock
        )
        self.journal: List[JournalEntry] = []

    # -- ALLOC ---------------------------------------------------------------
    def alloc(self, total_pages: int, page_size: int) -> int:
        if total_pages & (total_pages - 1):
            raise ValueError("total_pages must be a power of two (paper §II)")
        with self._lock:
            blob_id = self._blob_id_counter
            self._blob_id_counter += 1
            self._blobs[blob_id] = _BlobState(
                total_pages=total_pages,
                page_size=page_size,
                page_versions=np.zeros(total_pages, dtype=np.int64),
            )
            self.journal.append(
                JournalEntry("alloc", blob_id, total_pages=total_pages, page_size=page_size)
            )
            return blob_id

    def blob_info(self, blob_id: int) -> Tuple[int, int]:
        with self._lock:
            st = self._blobs[blob_id]
            return st.total_pages, st.page_size

    def blob_ids(self) -> List[int]:
        """Every allocated blob id (public API for invariant checkers — the
        interleaving explorer sweeps all blobs without reaching into
        ``_blobs``)."""
        with self._lock:
            return sorted(self._blobs)

    # -- WRITE protocol --------------------------------------------------------
    def assign_version(
        self, blob_id: int, offset: int, size: int
    ) -> Tuple[int, List[BorderLink]]:
        """Step 2 of a WRITE: get a fresh version number + precomputed border
        links. Thin wrapper over :meth:`assign_versions` — journal replay
        (:meth:`recover`) sees identical per-version ``assign`` entries either
        way."""
        return self.assign_versions(blob_id, [(offset, size)])[0]

    def assign_versions(
        self, blob_id: int, spans: Sequence[Tuple[int, int]]
    ) -> List[Tuple[int, List[BorderLink]]]:
        """Batch version assignment for a multi-patch ``writev``: ONE manager
        lock acquisition covers every ``(offset, size)`` span, in span order.
        The serialized section stays O(Σ size + patches·log total_pages) —
        each span's border links are computed against the interval history of
        all earlier assignments *including the preceding spans of this very
        batch*, exactly as a loop of :meth:`assign_version` would see them.
        One ``assign`` journal entry is appended per span, so journals are
        byte-compatible with the single-patch API."""
        with self._lock:
            st = self._blobs[blob_id]
            for offset, size in spans:
                if offset < 0 or size <= 0 or offset + size > st.total_pages:
                    raise ValueError("write range out of bounds")
            pv = st.page_versions
            assert pv is not None

            def version_of_segment(o: int, s: int) -> int:
                # Most recent version < `version` intersecting [o, o+s):
                # range-max over the per-page latest-version array, which at
                # this point reflects exactly versions 1..version-1.
                return int(pv[o : o + s].max(initial=ZERO_VERSION))

            out: List[Tuple[int, List[BorderLink]]] = []
            for offset, size in spans:
                version = st.assigned + 1
                st.withdrawn.discard(version)  # the number has a writer again
                links = compute_border_links(
                    st.total_pages, offset, size, version_of_segment
                )
                # Commit the assignment only after computing links.
                st.assigned = version
                st.intervals[version] = (offset, size)
                pv[offset : offset + size] = version
                self.journal.append(
                    JournalEntry("assign", blob_id, version, offset, size)
                )
                out.append((version, links))
            return out

    def report_success(self, blob_id: int, version: int) -> int:
        """Final step of a WRITE. Publishes the maximal completed prefix and
        returns the new latest published version."""
        return self.report_successes(blob_id, [version])

    def report_successes(self, blob_id: int, versions: Sequence[int]) -> int:
        """Batched :meth:`report_success` for a multi-patch ``writev``: all of
        the batch's versions complete under ONE lock acquisition (one
        ``complete`` journal entry per version, so journals stay
        byte-compatible with the single-version API)."""
        with self._lock:
            st = self._blobs[blob_id]
            # writer-recovery race: if a death verdict abandoned these
            # versions while their (actually live, e.g. partitioned) writer
            # was mid-flight, the write MUST surface as a failure — marking
            # an aborted hole "complete" would silently ack a write that
            # will never publish
            stale = sorted(
                v for v in versions if v in st.aborted or v in st.withdrawn
            )
            if stale:
                raise VersionAbandoned(
                    f"versions {stale} of blob {blob_id} were abandoned "
                    "by writer recovery before their writer reported"
                )
            for version in versions:
                st.completed.add(version)
                self.journal.append(JournalEntry("complete", blob_id, version))
            self._advance_published_locked(st)
            return st.published

    def abandon(self, blob_id: int, versions: Sequence[int]) -> "set":
        """Withdraw assigned-but-unreportable versions after a failed WRITE.

        Without this, in-order publication would wedge forever behind a
        version whose writer died mid-flight. Two cases, handled newest-first:

        * the version is still the *latest* assignment — it is fully erased
          (interval history and the per-page version array are rolled back),
          so no future border link can ever reference it and the version
          number is reused by the next writer;
        * a concurrent writer was assigned after it — the version becomes an
          *aborted hole*: publication skips over it, reads of it are
          rejected, and its interval stays in the history, but the per-page
          latest-version array is rolled back past it so every writer
          assigned *from now on* links straight to live versions. Writers
          assigned *before* the abandon may already have woven border links
          against the hole; those dangling links are resolved on the read
          path via :meth:`redirect_read_link` and eventually unlinked by the
          repair service's metadata scrub.

        Returns the set of versions that became holes (empty when everything
        was erased) — the caller must NOT scrub a hole's stored pages/nodes
        inline, since pre-abandon writers' trees may reference them (the
        scrub runs later, once the read-path redirect makes it safe).
        """
        holes: set = set()
        with self._lock:
            st = self._blobs[blob_id]
            pv = st.page_versions
            assert pv is not None

            def rolled_back(offset: int, size: int) -> np.ndarray:
                """What the per-page latest-version array should say for
                ``[offset, offset+size)`` given only live (non-aborted)
                interval history."""
                seg = np.full(size, ZERO_VERSION, dtype=np.int64)
                for w, (wo, ws) in st.intervals.items():
                    if w in st.aborted:
                        continue  # holes must never resurface in pv
                    lo, hi = max(offset, wo), min(offset + size, wo + ws)
                    if lo < hi:
                        np.maximum(
                            seg[lo - offset : hi - offset],
                            w,
                            out=seg[lo - offset : hi - offset],
                        )
                return seg

            for v in sorted(set(versions), reverse=True):
                if (
                    v <= st.published
                    or v > st.assigned
                    or v in st.completed
                    or v in st.aborted
                ):
                    continue  # published/completed versions are past abandoning
                self.journal.append(JournalEntry("abandon", blob_id, v))
                if v == st.assigned:
                    offset, size = st.intervals.pop(v)
                    st.assigned -= 1
                    st.withdrawn.add(v)
                    pv[offset : offset + size] = rolled_back(offset, size)
                else:
                    st.aborted.add(v)
                    holes.add(v)
                    # roll pv back over the hole too: pages still carrying v
                    # recompute from live history, pages a later writer
                    # already overwrote stay theirs
                    offset, size = st.intervals[v]
                    span = pv[offset : offset + size]
                    mine = span == v
                    if mine.any():
                        span[mine] = rolled_back(offset, size)[mine]
            if holes:
                st.aborted_view = frozenset(st.aborted)
            self._advance_published_locked(st)
        return holes

    def _advance_published_locked(self, st: _BlobState) -> None:
        """Publish the maximal completed-or-aborted prefix (caller holds the
        lock). Aborted versions are skipped over but stay in ``st.aborted`` so
        reads can reject them."""
        while (st.published + 1) in st.completed or (st.published + 1) in st.aborted:
            st.completed.discard(st.published + 1)
            st.published += 1
        self._published_cv.notify_all()

    # -- READ protocol ---------------------------------------------------------
    @staticmethod
    def _latest_readable_locked(st: _BlobState) -> int:
        """Latest readable published version (caller holds the lock):
        aborted holes at the publish frontier are walked back over (an
        aborted version has no tree)."""
        v = st.published
        while v in st.aborted:
            v -= 1
        return v

    def latest_published(self, blob_id: int) -> int:
        """Latest *readable* published version."""
        with self._lock:
            return self._latest_readable_locked(self._blobs[blob_id])

    def resolve_read_version(
        self, blob_id: int, version: Optional[int]
    ) -> Tuple[int, int, int, int]:
        """One-lock READ setup: returns ``(total_pages, page_size, resolved,
        latest)`` where ``resolved`` is ``version`` (validated: published and
        not aborted) or the latest readable version when ``version`` is None.
        The serialized actor is consulted exactly once per read call."""
        with self._lock:
            st = self._blobs[blob_id]
            latest = self._latest_readable_locked(st)
            if version is None:
                resolved = latest
            else:
                if version > st.published:
                    raise ValueError(
                        f"version {version} not yet published (latest={st.published})"
                    )
                if version in st.aborted:
                    raise ValueError(
                        f"version {version} was abandoned by a failed writer"
                    )
                resolved = version
            return st.total_pages, st.page_size, resolved, latest

    def is_published(self, blob_id: int, version: int) -> bool:
        with self._lock:
            return version <= self._blobs[blob_id].published

    def is_aborted(self, blob_id: int, version: int) -> bool:
        """True if ``version`` was withdrawn by a failed writer (publication
        skipped over it; it was never readable). Version-watch subscriptions
        use this to step over holes without delivering them."""
        with self._lock:
            return version in self._blobs[blob_id].aborted

    def aborted_view(self, blob_id: int) -> frozenset:
        """Lock-free snapshot of the blob's aborted (hole) versions.

        The common case is the empty frozenset, letting read paths skip the
        dangling-link redirect entirely without touching the manager lock.
        Memory visibility is safe: a reader resolving its read version takes
        the manager lock *after* any abandon that published the hole, so the
        swapped-in frozenset (an immutable object, never mutated) is at
        least as fresh as the version being read."""
        return self._blobs[blob_id].aborted_view

    def repair_horizon(self, blob_id: int) -> Tuple[int, frozenset]:
        """The journal-covered repair window: ``(latest_published,
        aborted_view)`` read under ONE lock acquisition so the pair is
        mutually consistent. Repair passes (page re-replication, metadata
        re-replication) must only touch versions the journal vouches for —
        at or below the publish frontier and not an abandoned hole:
        everything above the frontier is an in-flight writer's private state
        (the writer fixes its own placements or gets withdrawn), and holes
        are the scrub's business. Both values derive from journaled
        transitions (``publish``/``abandon``), so a recovered manager
        replays the identical horizon and a repair decided before the crash
        stays valid after it."""
        with self._lock:
            st = self._blobs[blob_id]
            return self._latest_readable_locked(st), st.aborted_view

    def redirect_read_link(
        self, blob_id: int, version: int, offset: int, size: int
    ) -> int:
        """Resolve a dangling border link: a stored tree node links segment
        ``[offset, offset+size)`` (in pages) to aborted ``version``. Returns
        the most recent live version below it whose interval intersects the
        segment — the version whose tree holds the segment's real content
        (aborted versions in between never stored data, so skipping them is
        exactly COW semantics) — or ``ZERO_VERSION`` when no live writer
        ever touched the segment (implicit zeros)."""
        with self._lock:
            st = self._blobs[blob_id]
            best = ZERO_VERSION
            for w, (wo, ws) in st.intervals.items():
                if w >= version or w <= best or w in st.aborted:
                    continue
                if wo < offset + size and offset < wo + ws:
                    best = w
            return best

    def wait_published(
        self,
        blob_id: int,
        version: int,
        timeout: Optional[float] = None,
        *,
        fail_on_withdrawn: bool = True,
    ) -> bool:
        """Block until ``version`` publishes; ``False`` on timeout.

        Raises :class:`VersionAbandoned` when ``version`` was withdrawn by a
        failed writer — whether it became an aborted hole or was erased
        outright. :meth:`abandon` notifies this condition, so a waiter whose
        version is abandoned *mid-wait* fails fast instead of burning its
        whole timeout on a version that can never arrive as written.

        ``fail_on_withdrawn=False`` is for subscription waiters
        (:class:`~repro.core.cluster.VersionWatch`): an *erased* version
        number may be reissued to the next writer, so a watch keeps waiting
        for the number to publish under its new owner — only aborted holes
        (which can never publish) raise."""
        st = self._blobs[blob_id]

        def resolved() -> bool:
            if st.published >= version or version in st.aborted:
                return True
            return fail_on_withdrawn and version in st.withdrawn

        with self._published_cv:
            if not self._published_cv.wait_for(resolved, timeout=timeout):
                return False
            if version in st.aborted or (
                fail_on_withdrawn and version in st.withdrawn
            ):
                raise VersionAbandoned(
                    f"version {version} of blob {blob_id} was abandoned by a "
                    f"failed writer"
                )
            return True

    def interval_of(self, blob_id: int, version: int) -> Tuple[int, int]:
        with self._lock:
            return self._blobs[blob_id].intervals[version]

    def assigned_versions(self, blob_id: int) -> int:
        with self._lock:
            return self._blobs[blob_id].assigned

    # -- fault tolerance ---------------------------------------------------------
    @classmethod
    def recover(cls, journal: List[JournalEntry]) -> Tuple["VersionManager", Dict[int, List[int]]]:
        """Rebuild a manager from a journal replay.

        Returns ``(manager, orphans)`` where ``orphans[blob_id]`` lists
        versions that were assigned but never reported success — a recovering
        deployment either waits for their writers or garbage-collects their
        pages. Publishing stops before the first orphan, preserving
        serializability across the crash.
        """
        vm = cls()
        completed: Dict[int, set] = {}
        for entry in journal:
            if entry.op == "alloc":
                bid = vm.alloc(entry.total_pages, entry.page_size)
                assert bid == entry.blob_id
                completed[bid] = set()
            elif entry.op == "assign":
                version, _ = vm.assign_version(entry.blob_id, entry.offset, entry.size)
                assert version == entry.version
            elif entry.op == "complete":
                completed[entry.blob_id].add(entry.version)
            elif entry.op == "abandon":
                vm.abandon(entry.blob_id, [entry.version])
        orphans: Dict[int, List[int]] = {}
        for bid, done in completed.items():
            for v in sorted(done):
                vm.report_success(bid, v)
            st = vm._blobs[bid]
            orphans[bid] = [
                v
                for v in range(1, st.assigned + 1)
                if v not in done and v not in st.aborted and v > st.published
            ]
        return vm, orphans
