"""Cluster / Session / BlobHandle: the layered client API (paper §III).

The paper's architecture separates the *shared infrastructure* — version
manager, metadata providers, data providers — from the *client library* each
concurrent reader/writer embeds (§III.A vs §III.B). This module makes that
split explicit in the API, the way BlobSeer exposes its versioned
``create/read/write/clone`` client interface:

* :class:`Cluster` owns the shared plane: the :class:`VersionManager` (the
  system's only serialization point), the :class:`MetadataDHT`, the
  :class:`ProviderManager` and its :class:`DataProvider`\\ s, the
  :class:`ReplicaBalancer`, the data-plane thread pool, and a **node-level
  shared page-cache tier** (many detector threads on one node, one cache).
* :class:`Session` (``cluster.session()``) owns per-client state: a private
  write-through page cache in front of the shared tier, its own
  :class:`TrafficStats`, the ``write_async`` bounded in-flight window, and
  replica-choice randomness. N sessions on one cluster model the paper's
  N-concurrent-clients topology in-process without N copies of the providers.
* :class:`BlobHandle` (``session.open(blob_id)``) carries the fine-grain data
  ops — ``read``/``readv``/``write``/``writev``/``write_async`` — plus
  :meth:`BlobHandle.snapshot`/:meth:`BlobHandle.at` returning an immutable
  :class:`Snapshot` that pins a published version for lock-free repeated
  reads (no version-manager round-trip per read, and GC will not collect a
  pinned version), and :meth:`BlobHandle.watch`, a publish-subscription built
  on ``VersionManager.wait_published`` so readers react to newly published
  versions instead of polling.

Cache coherence across sessions is the publish frontier: a session's private
cache is write-through under the versions the manager assigned to it, so the
moment one of its writes publishes, its own re-reads are RAM hits (reads of
still-unpublished versions are rejected at the frontier for everyone,
including the writer). The shared tier is filled exclusively by the read
path, which resolves and validates the version against the publish frontier
first — so an unpublished page can never enter the shared tier, and a
cross-session read of an unpublished version is impossible by construction.

The write path is the overlapped pipeline of the write-plane PR (data puts
launched first; version assignment, tree weaving and per-shard node puts all
run while data is in flight; one join before success is reported; failures
clean up after themselves via ``VersionManager.abandon``), and transport is
zero-copy end to end. See :mod:`repro.core.blob` for the deprecated
single-object facade.
"""

from __future__ import annotations

import dataclasses
import functools
import random
import threading
import time
from collections import defaultdict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.dht import (
    MetadataDHT,
    ProviderFailed,
    RetryPolicy,
    TrafficStats,
    page_checksum,
)
from repro.core.page_cache import PageCache, ZERO_PAGE_CHARGE
from repro.core.page_directory import PageDirectory
from repro.core.prefetch import PrefetchConfig, StridePrefetcher, WatchWarmer
from repro.core.provider import DataProvider, HealthConfig, ProviderManager
from repro.core.repair import RepairService
from repro.core.replica_balancer import BalancerConfig, ReplicaBalancer
from repro.core.segment_tree import (
    NodeKey,
    PageRef,
    TreeNode,
    ZERO_VERSION,
    build_write_tree,
    traverse_batch,
)
from repro.core.version_manager import VersionAbandoned, VersionManager

#: Default per-session (private) page-cache budget in bytes; ``cache_bytes=0``
#: disables the private tier.
DEFAULT_CACHE_BYTES = 64 << 20
#: Default node-level shared cache tier budget in bytes;
#: ``shared_cache_bytes=0`` disables the shared tier (each session then runs
#: a standalone private cache, the pre-split topology).
DEFAULT_SHARED_CACHE_BYTES = 256 << 20


# NOTE: RetryPolicy lives in repro.core.dht now (both planes share it); the
# import above keeps ``from repro.core.cluster import RetryPolicy`` working.


@dataclasses.dataclass
class ReadResult:
    latest_published: int
    data: np.ndarray


@functools.lru_cache(maxsize=8)
def _zero_page(page_size: int) -> np.ndarray:
    page = np.zeros(page_size, dtype=np.uint8)
    page.flags.writeable = False
    return page


def _merge_ranges(pages: Sequence[int]) -> List[Tuple[int, int]]:
    """Collapse a sorted page-index list into (offset, size) runs."""
    ranges: List[Tuple[int, int]] = []
    for p in pages:
        if ranges and ranges[-1][0] + ranges[-1][1] == p:
            ranges[-1] = (ranges[-1][0], ranges[-1][1] + 1)
        else:
            ranges.append((p, 1))
    return ranges


class _PageFetchStream:
    """Incremental data-plane fetcher — the streaming half of the read
    pipeline.

    :meth:`submit` may be called concurrently from metadata-RPC workers as
    traversal levels resolve leaves: each call immediately launches one
    aggregated ``get_pages`` future per serving provider for the batch's NEW
    pages (replica-spread exactly like the phased path), so data transfer is
    in flight while deeper metadata rounds are still running. :meth:`join`
    is the pipeline's single barrier: it collects every launched future,
    runs per-page replica fallback for failed provider batches, feeds the
    balancer's heat counters once, and returns the assembled
    ``{page_index: page_or_None}`` map."""

    __slots__ = ("_session", "_page_size", "_lock", "_seen", "_read_load",
                 "_queues", "_scheduled", "_futures", "_result")

    def __init__(self, session: "Session", page_size: int) -> None:
        self._session = session
        self._page_size = page_size
        self._lock = make_lock("_PageFetchStream._lock")
        self._seen: Set[int] = set()
        self._read_load: Optional[Dict[int, int]] = None
        #: pending items per provider, drained by at most one in-flight
        #: drain task per provider — emissions that land while a provider's
        #: drain is still queued MERGE into its batch, so near-simultaneous
        #: leaf deliveries (the common case: one level's shard RPCs complete
        #: together) keep the one-aggregated-RPC-per-provider shape
        self._queues: Dict[int, List[Tuple[int, int, TreeNode]]] = {}
        self._scheduled: Set[int] = set()
        self._futures: List[Future] = []
        self._result: Dict[int, Optional[np.ndarray]] = {}

    def submit(self, leaves: Dict[int, Optional[TreeNode]]) -> None:
        """Launch fetches for every not-yet-seen page of ``leaves`` (pages
        are deduplicated across calls, so the level-end catch-all emission
        can safely re-deliver leaves a streaming ``get_nodes`` already
        handed over). ``None`` leaves (implicit zero pages) are recorded as
        results directly — nothing to fetch."""
        session = self._session
        with self._lock:
            for page_index, leaf in leaves.items():
                if page_index in self._seen:
                    continue
                self._seen.add(page_index)
                if leaf is None:
                    self._result[page_index] = None
                    continue
                if session.replica_spread and len(leaf.all_page_refs()) > 1:
                    # stats snapshot deferred until a leaf actually has a
                    # choice — single-replica reads skip the global lock
                    if self._read_load is None:
                        self._read_load = session.cluster.stats.read_bytes_snapshot()
                    pid, key = session._choose_ref(
                        leaf, self._read_load, self._page_size
                    )
                else:
                    pid, key = leaf.page  # type: ignore[misc]
                self._queues.setdefault(pid, []).append((page_index, key, leaf))
                if pid not in self._scheduled:
                    self._scheduled.add(pid)
                    self._futures.append(
                        session._pool.submit(self._drain, pid)
                    )

    def _drain(
        self, pid: int
    ) -> Tuple[int, List[Tuple[int, int, TreeNode]], Optional[Dict[int, np.ndarray]]]:
        """One aggregated ``get_pages`` RPC covering everything queued for
        ``pid`` at execution time."""
        with self._lock:
            items = self._queues.pop(pid, [])
            self._scheduled.discard(pid)
        if not items:
            return pid, items, {}
        return pid, items, self._session._get_batch(pid, items)

    def submit_partial(self, nodes: Dict[NodeKey, TreeNode]) -> None:
        """Adapter for :meth:`MetadataDHT.get_nodes`'s ``on_partial`` hook:
        every leaf in a shard's partial result is a wanted page (the
        traversal only ever asks for wanted keys), so fetch it right away."""
        leaves = {
            key.offset: node for key, node in nodes.items() if node.is_leaf
        }
        if leaves:
            self.submit(leaves)

    def join(self) -> Dict[int, Optional[np.ndarray]]:
        session = self._session
        #: (page, leaf, skip_pid, corrupt_refs): pages needing per-page
        #: replica fallback — the whole batch when the provider failed, or
        #: individual pages whose fetched bytes failed checksum verification
        #: (those also carry the corrupt copy's ref so it gets repaired)
        fallback: List[Tuple[int, TreeNode, int, Tuple]] = []
        fetched_leaves: List[TreeNode] = []
        # drain futures may schedule no successors, so a single pass over
        # the (append-only) future list until it stops growing joins all
        done = 0
        while True:
            with self._lock:
                futures = list(self._futures)
            if done == len(futures):
                break
            for f in futures[done:]:
                pid, items, got = f.result()
                fetched_leaves.extend(leaf for _, _, leaf in items)
                if got is None:
                    fallback.extend((p, leaf, pid, ()) for p, _, leaf in items)
                else:
                    self._result.update(got)
                    # pages absent from a successful batch failed their
                    # checksum: fall back AND repair the corrupt copy
                    fallback.extend(
                        (p, leaf, pid, ((pid, key),))
                        for p, key, leaf in items
                        if p not in got
                    )
            done = len(futures)
        if fallback:
            # replica fallback in parallel, skipping the observed-dead choice;
            # tracked in _futures so quiesce() covers a fallback that raises
            # mid-join (all replicas dead) with siblings still in flight.
            # This read is DEGRADED: it completed, but only via surviving
            # replicas — count it so operators see reads running on
            # reduced redundancy before repair restores the factor
            session._record_fallback(len(fallback))
            session._record_degraded(1)
            fb = [
                session._pool.submit(
                    session._fetch_single, p, leaf, skip, corrupt
                )
                for p, leaf, skip, corrupt in fallback
            ]
            with self._lock:
                self._futures.extend(fb)
            for (p, _, _, _), f in zip(fallback, fb):
                self._result[p] = f.result()
        if session.cluster.replica_balancer is not None and fetched_leaves:
            session.cluster.replica_balancer.note_fetches(fetched_leaves)
        return self._result

    def quiesce(self) -> None:
        """Error path: wait out every in-flight fetch without raising, so an
        aborted read leaves no future scribbling into shared state."""
        done = 0
        while True:
            with self._lock:
                futures = list(self._futures)
            if done == len(futures):
                break
            for f in futures[done:]:
                f.exception()
            done = len(futures)


class Cluster:
    """The shared plane: the five actors of the paper's architecture plus the
    node-level shared cache tier, wired once and shared by every
    :class:`Session`."""

    def __init__(
        self,
        n_data_providers: int = 4,
        n_metadata_providers: int = 4,
        page_replication: int = 1,
        metadata_replication: int = 1,
        max_workers: int = 8,
        shared_cache_bytes: int = DEFAULT_SHARED_CACHE_BYTES,
        hot_replicas: bool = True,
        balancer_config: Optional[BalancerConfig] = None,
        page_service_seconds: float = 0.0,
        metadata_latency_seconds: float = 0.0,
        retry_policy: Optional[RetryPolicy] = None,
        health: Optional[HealthConfig] = None,
        metadata_timeout_seconds: Optional[float] = None,
        page_directory_capacity: int = 4096,
        version_manager: Optional[VersionManager] = None,
        provider_manager: Optional[ProviderManager] = None,
        metadata: Optional[MetadataDHT] = None,
    ) -> None:
        #: cluster-wide aggregate traffic (every session records here too)
        self.stats = TrafficStats()
        #: RPC retry/backoff policy, shared by BOTH planes (injectable for
        #: chaos tests); ``health`` likewise configures both health machines
        self.retry_policy = retry_policy or RetryPolicy()
        #: federated mode (``Federation``): the three shared-plane actors are
        #: INJECTED — this cluster is one access node over a substrate it does
        #: not own, so it must not register providers, wire repair hooks, or
        #: tear the substrate down on close
        self._owns_substrate = (
            version_manager is None
            and provider_manager is None
            and metadata is None
        )
        self.version_manager = version_manager or VersionManager()
        self.provider_manager = provider_manager or ProviderManager(
            replication=page_replication, stats=self.stats, health=health
        )
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self.metadata = metadata or MetadataDHT(
            n_metadata_providers,
            replication=metadata_replication,
            stats=self.stats,
            executor=self._pool,
            rpc_latency_seconds=metadata_latency_seconds,
            retry_policy=self.retry_policy,
            health=health,
            rpc_timeout_seconds=metadata_timeout_seconds,
        )
        #: shared intra-node cache tier: filled ONLY by the read path (whose
        #: versions are validated against the publish frontier), hit by every
        #: session — the coherence argument is published-version immutability
        #: plus frontier gating, never an invalidation protocol
        self.shared_cache: Optional[PageCache] = (
            PageCache(shared_cache_bytes) if shared_cache_bytes else None
        )
        self.page_service_seconds = page_service_seconds
        if self._owns_substrate:
            for i in range(n_data_providers):
                self.provider_manager.register(
                    DataProvider(i, page_service_seconds)
                )
        self.replica_balancer: Optional[ReplicaBalancer] = (
            ReplicaBalancer(
                self.provider_manager, self.metadata, self.stats, balancer_config
            )
            if hot_replicas
            else None
        )
        #: self-healing: when the health machine declares a provider dead the
        #: manager's ``on_dead`` hook queues a background re-replication pass
        #: on the aux pool (the hook fires OUTSIDE the manager lock, so the
        #: level-4 ``_aux_lock`` acquisition below it is legal)
        self.repair_service = RepairService(self)
        if self._owns_substrate:
            self.provider_manager.on_dead = self.repair_service.schedule
            #: the metadata plane gets the same treatment: a shard death
            #: verdict queues a repair pass, whose metadata half re-replicates
            #: the dead replica's node set from survivors once it rejoins
            self.metadata.on_dead = self.repair_service.schedule
            self._next_provider_id = n_data_providers
        else:
            # the Federation wires ONE repair service (the home node's) to the
            # shared substrate's death verdicts — per-node hooks would race
            # concurrent repair passes over the same providers
            self._next_provider_id = (
                max(
                    (p.provider_id for p in self.provider_manager.providers()),
                    default=-1,
                )
                + 1
            )
        self._membership_lock = make_lock("Cluster._membership_lock")
        #: registered sessions (GC must purge every private cache tier)
        self._sessions: List["Session"] = []
        self._sessions_lock = make_lock("Cluster._sessions_lock")
        #: snapshot pins: blob_id -> version -> refcount; GC keeps pinned
        #: versions alive no matter what ``keep_versions`` says
        self._pins: Dict[int, Dict[int, int]] = {}
        self._pins_lock = make_lock("Cluster._pins_lock")
        #: linearizes snapshot creation against GC: a pin is taken either
        #: strictly before a GC pass reads the pin set (and is honored) or
        #: strictly after the pass completes — never mid-sweep, where the
        #: just-pinned version could still be collected (``_pins_lock`` alone
        #: cannot give that guarantee; it is held only for the dict ops)
        self._gc_guard = make_lock("Cluster._gc_guard")
        #: cluster-wide content-addressed page registry (the serving plane's
        #: cross-user prefix cache): published pages keyed by content, each
        #: entry snapshot-pinning its version so GC never collects a page the
        #: directory still advertises
        self.page_directory = PageDirectory(self, capacity=page_directory_capacity)
        #: monotonically numbers sessions (diversifies their RNG streams)
        self._session_counter = 0
        self._max_workers = max_workers
        #: auxiliary pool for background cache fills (stride prefetch): a
        #: fill task joins nested fan-out futures that run on the MAIN pool,
        #: and a main-pool worker doing that join could deadlock a saturated
        #: pool — so background fills get their own lane (lazily spawned)
        self._aux_pool: Optional[ThreadPoolExecutor] = None
        self._aux_lock = make_lock("Cluster._aux_lock")
        self._aux_closed = False
        self._closed = False
        #: live watch-warmers, stopped on close
        self._warmers: List[WatchWarmer] = []
        self._warmers_lock = make_lock("Cluster._warmers_lock")
        # -- federation plumbing (set by repro.core.federation.Federation) --
        #: back-reference when this cluster is one node of a Federation
        self._federation = None
        self._node_id: Optional[int] = None
        #: lease guard: returns True when this node's GC lease is valid (the
        #: cache tiers may serve); returning False means the node is FENCED —
        #: the read path falls through to the providers with no cache fills
        self._lease_guard: Optional[Callable[[], bool]] = None
        #: node gate: raises ``ProviderFailed`` when the node itself is down
        #: (killed/wedged by the chaos harness) — data ops fail at the door
        self._node_gate: Optional[Callable[[], None]] = None
        #: snapshot-pin forwarding to the federation's GC coordinator (pins
        #: must be visible to GC passes initiated from ANY node)
        self._pin_sink: Optional[Callable[[int, int], None]] = None
        self._unpin_sink: Optional[Callable[[int, int], None]] = None

    # -- sessions ------------------------------------------------------------
    def session(
        self,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        replica_spread: bool = True,
        sync_write: bool = False,
        sync_read: bool = False,
        max_inflight_writes: int = 8,
        prefetch: Optional[PrefetchConfig] = None,
    ) -> "Session":
        """Create one client :class:`Session` on this cluster. Every
        concurrent reader/writer of the paper's topology is one session.

        ``sync_read=True`` keeps the pre-pipeline *phased* read plane (full
        metadata traversal before the first page fetch — the ``sync-read``
        benchmark baseline); ``prefetch`` attaches a
        :class:`~repro.core.prefetch.StridePrefetcher` with the given config
        so sequential readers get bounded readahead into the shared tier."""
        with self._sessions_lock:
            index = self._session_counter
            self._session_counter += 1
        sess = Session(
            self,
            cache_bytes=cache_bytes,
            replica_spread=replica_spread,
            sync_write=sync_write,
            sync_read=sync_read,
            max_inflight_writes=max_inflight_writes,
            prefetch=prefetch,
            _index=index,
        )
        with self._sessions_lock:
            self._sessions.append(sess)
        return sess

    def _forget_session(self, sess: "Session") -> None:
        with self._sessions_lock:
            try:
                self._sessions.remove(sess)
            except ValueError:
                pass

    def sessions(self) -> List["Session"]:
        with self._sessions_lock:
            return list(self._sessions)

    # -- background fills (prefetch / warming) --------------------------------
    def _aux_submit(self, fn, *args) -> Future:
        """Run a background cache-fill task on the auxiliary pool — never on
        the main data-plane pool, whose workers must stay join-free. Raises
        ``RuntimeError`` once the cluster is closed (callers drop the fill)
        instead of silently resurrecting a pool nothing would shut down."""
        with self._aux_lock:
            if self._aux_closed:
                raise RuntimeError("cluster is closed")
            if self._aux_pool is None:
                self._aux_pool = ThreadPoolExecutor(
                    max_workers=max(4, self._max_workers // 2),
                    thread_name_prefix="prefetch",
                )
            return self._aux_pool.submit(fn, *args)

    def warm_on_publish(
        self,
        blob_id: int,
        top_pages: int = 256,
        frame_versions: Optional[int] = None,
    ) -> WatchWarmer:
        """Start a :class:`~repro.core.prefetch.WatchWarmer` for ``blob_id``:
        every freshly published version (every ``frame_versions``-th, if set)
        gets its hottest pages pulled into the shared tier before detector
        sessions ask. The warmer is stopped automatically on :meth:`close`;
        call :meth:`WatchWarmer.stop` to retire it earlier."""
        warmer = WatchWarmer(
            self, blob_id, top_pages=top_pages, frame_versions=frame_versions
        )
        with self._warmers_lock:
            self._warmers.append(warmer)
        return warmer

    # -- elasticity ----------------------------------------------------------
    def add_data_provider(self) -> int:
        with self._membership_lock:
            pid = self._next_provider_id
            self._next_provider_id += 1
        self.provider_manager.register(DataProvider(pid, self.page_service_seconds))
        return pid

    # -- ALLOC ---------------------------------------------------------------
    def alloc(self, size_bytes: int, page_size: int) -> int:
        if page_size & (page_size - 1):
            raise ValueError("page_size must be a power of two")
        if size_bytes % page_size:
            raise ValueError("blob size must be a multiple of page_size")
        total_pages = size_bytes // page_size
        return self.version_manager.alloc(total_pages, page_size)

    # -- snapshot pins --------------------------------------------------------
    def pin_version(self, blob_id: int, version: int) -> None:
        if version == ZERO_VERSION:
            return  # the implicit zero version has nothing to collect
        sink = self._pin_sink
        if sink is not None:
            # federated: register the pin at the GC coordinator FIRST — if the
            # node is partitioned from the coordinator this raises, and
            # refusing the pin is the safe failure (a locally-recorded pin the
            # coordinator cannot see would not protect the version from a GC
            # initiated on another node)
            sink(blob_id, version)
        with self._pins_lock:
            blob_pins = self._pins.setdefault(blob_id, {})
            blob_pins[version] = blob_pins.get(version, 0) + 1

    def unpin_version(self, blob_id: int, version: int) -> None:
        with self._pins_lock:
            blob_pins = self._pins.get(blob_id)
            if not blob_pins or version not in blob_pins:
                return
            blob_pins[version] -= 1
            if blob_pins[version] <= 0:
                del blob_pins[version]
            if not blob_pins:
                del self._pins[blob_id]
        sink = self._unpin_sink
        if sink is not None:
            try:  # best-effort: a dead node's pins are reclaimed with its lease
                sink(blob_id, version)
            except ProviderFailed:
                pass

    def pinned_versions(self, blob_id: int) -> Set[int]:
        with self._pins_lock:
            return set(self._pins.get(blob_id, ()))

    def local_pins(self) -> Dict[Tuple[int, int], int]:
        """Snapshot of every live snapshot pin on this node, keyed
        ``(blob_id, version)`` — the rejoin-time resync payload for the
        federated GC coordinator (unpins issued while the node was
        unreachable never made it there)."""
        with self._pins_lock:
            return {
                (blob_id, version): count
                for blob_id, blob_pins in self._pins.items()
                for version, count in blob_pins.items()
            }

    def pin_published(self, blob_id: int, version: Optional[int] = None) -> int:
        """Validate ``version`` against the publish frontier and snapshot-pin
        it, atomically with respect to GC passes (``None`` pins the latest
        published version). Raises ``ValueError`` for versions beyond the
        frontier or abandoned ones — this is the gate that makes registering
        (and therefore cross-session reading) unpublished data impossible.
        Returns the version actually pinned."""
        with self._gc_guard:
            _, _, resolved, _ = self.version_manager.resolve_read_version(
                blob_id, version
            )
            self.pin_version(blob_id, resolved)
        return resolved

    # -- fencing (federated mode) ----------------------------------------------
    def caches_servable(self) -> bool:
        """True when the cache tiers may serve frontier-validated reads.

        Standalone clusters always serve. A federated node consults its lease
        guard: an expired lease means a remote ``Federation.gc`` may already
        have reclaimed versions this node's tiers still hold, so the node is
        *fenced* — reads fall through to the providers (always correct: GC
        never collects a version another node still needs) until the node
        rejoins at the current epoch."""
        guard = self._lease_guard
        return True if guard is None else guard()

    def fence_caches(self) -> None:
        """Drop every cache tier on this node (shared + all session privates).
        Called when the node's lease lapses or it rejoins an advanced GC
        epoch: anything cached may be stale relative to reclaims it never
        acked, so the conservative purge is everything."""
        if self.shared_cache is not None:
            self.shared_cache.clear()
        for sess in self.sessions():
            if sess.cache is not None:
                sess.cache.clear()

    def _check_node_up(self) -> None:
        gate = self._node_gate
        if gate is not None:
            gate()

    # -- GC (paper future work) ----------------------------------------------
    def gc(
        self,
        blob_id: int,
        keep_versions: Sequence[int],
        _local: bool = False,
    ) -> Tuple[int, int]:
        """Drop all tree nodes / pages unreachable from ``keep_versions``
        (plus every snapshot-pinned version — a live :class:`Snapshot` keeps
        its version readable no matter what the GC caller asks for).

        Must be invoked only when no concurrent accesses target the dropped
        versions (the paper's "ordered by the client" semantics). Dropped
        versions are purged from the shared cache tier AND from every
        registered session's private cache, so no client on this node can
        serve a collected version from RAM — the local half of GC↔cache
        coherence (a *distributed* deployment still needs a GC epoch/lease
        protocol before remote nodes' caches can be trusted). Promotion
        passes are paused for the duration, and snapshot creation serializes
        against the pass (``_gc_guard``), so a pin can never land mid-sweep
        and lose its version. Returns (nodes_freed, pages_freed).

        On a federated node this delegates to ``Federation.gc`` — versions
        are reclaimed only under the epoch/lease protocol, after every live
        node acked the purge or its lease expired (``_local=True`` is the
        federation's internal re-entry for the home node's storage sweep)."""
        fed = self._federation
        if fed is not None and not _local:
            return fed.gc(blob_id, keep_versions)
        with self._gc_guard:
            keep = set(keep_versions) | self.pinned_versions(blob_id)
            if fed is not None:
                # the coordinator's sweep window opens INSIDE this node's
                # gc guard: coordinator pins are snapshotted here, and pin
                # requests from other nodes block until the sweep closes —
                # the federated analog of the single-node pin linearization
                # (pinners on THIS node block on the gc guard itself)
                keep |= fed.coordinator.begin_sweep(blob_id)
            try:
                if self.replica_balancer is not None:
                    # repair_service aliases the balancer's _rebalance_lock,
                    # so pausing the balancer excludes repair passes too
                    with self.replica_balancer.paused():
                        return self._gc_locked(blob_id, keep)
                with self.repair_service.paused():
                    return self._gc_locked(blob_id, keep)
            finally:
                if fed is not None:
                    fed.coordinator.end_sweep()

    def _gc_locked(self, blob_id: int, keep_versions: Set[int]) -> Tuple[int, int]:
        vm = self.version_manager
        total_pages, _ = vm.blob_info(blob_id)
        latest = vm.latest_published(blob_id)
        keep = sorted(v for v in keep_versions if v != ZERO_VERSION)
        aborted = vm.aborted_view(blob_id)
        reachable_nodes: Set[NodeKey] = set()
        reachable_pages: Set[PageRef] = set()

        def mark(version: int, offset: int, size: int) -> None:
            if version in aborted:
                # dangling link into an abandoned write: resolve it the same
                # way the read path does, so marking neither crashes on the
                # missing node nor roots the hole's wreckage
                version = vm.redirect_read_link(blob_id, version, offset, size)
            if version == ZERO_VERSION:
                return
            key = NodeKey(blob_id, version, offset, size)
            if key in reachable_nodes:
                return
            node = self.metadata.get_node(key)
            reachable_nodes.add(key)
            if node.is_leaf:
                reachable_pages.update(node.all_page_refs())
                return
            half = size // 2
            mark(node.left_version, offset, half)
            mark(node.right_version, offset + half, half)

        for v in keep:
            mark(v, 0, total_pages)

        # Enumerate every stored node of this blob and drop unreachable ones.
        doomed_nodes: List[NodeKey] = []
        doomed_pages: Set[PageRef] = set()
        for key, node in self.metadata.iter_nodes(blob_id):
            if key.version > latest and key.version not in aborted:
                continue  # never GC in-flight (unpublished) versions
            if key not in reachable_nodes:
                doomed_nodes.append(key)
                if node.is_leaf:
                    doomed_pages.update(ref for ref in node.all_page_refs())
        doomed_pages -= reachable_pages
        self.metadata.delete_nodes(doomed_nodes)
        if self.replica_balancer is not None:
            # demote-on-GC: the promoted copies die with the doomed leaves
            # (they are in the rewritten nodes' all_page_refs above); drop the
            # balancer's heat/promotion records so they can't be re-targeted
            self.replica_balancer.forget(doomed_nodes)
        by_provider: Dict[int, List[int]] = {}
        for pid, key in doomed_pages:
            by_provider.setdefault(pid, []).append(key)
        for pid, keys in by_provider.items():
            self.provider_manager.get_provider(pid).delete_pages(keys)
        self.provider_manager.release(sorted(doomed_pages))
        # cache coherence: purge the dropped versions from the shared tier
        # and from EVERY session's private cache. In-flight (unpublished)
        # versions stay cached — their pages were not collected above, and a
        # concurrent writer's write-through entries must survive another
        # session's GC call.
        keep_cached = set(keep) | {ZERO_VERSION}
        caches = [self.shared_cache] + [s.cache for s in self.sessions()]
        for cache in caches:
            if cache is not None:
                cache.drop_versions(blob_id, keep_cached, max_version=latest)
        return len(doomed_nodes), len(doomed_pages)

    # -- introspection --------------------------------------------------------
    def storage_bytes(self) -> int:
        return sum(p.used_bytes() for p in self.provider_manager.providers())

    def close(self) -> None:
        """Tear the shared plane down. Idempotent: concurrent/repeated calls
        after the first are no-ops. Warmer threads are joined with a bounded
        timeout so a wedged warmer cannot hang the close (and a watchdog-
        enabled test run cannot leak instrumented threads between tests)."""
        with self._aux_lock:
            if self._closed:
                return
            self._closed = True
        with self._warmers_lock:
            warmers, self._warmers = self._warmers, []
        for warmer in warmers:
            # warmers own sessions + fill tasks: stop them first
            warmer.stop(timeout=5.0)
        with self._aux_lock:
            aux, self._aux_pool = self._aux_pool, None
            self._aux_closed = True
        if aux is not None:
            aux.shutdown(wait=True)
        for sess in self.sessions():
            sess.close()
        if self._owns_substrate:
            self.metadata.close()  # federated nodes: the Federation owns it
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "Cluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class Session:
    """One client of the cluster: private cache tier, private traffic stats,
    private async-write window. Create via :meth:`Cluster.session`; get data
    ops via :meth:`Session.open` / :meth:`Session.create`.

    The fine-grain data plane (the paper's §III.B client protocol — the
    overlapped write pipeline and the batched, cache-fronted read path) lives
    here as ``_readv``/``_writev``; :class:`BlobHandle` is its public face.
    """

    def __init__(
        self,
        cluster: Cluster,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        replica_spread: bool = True,
        sync_write: bool = False,
        sync_read: bool = False,
        max_inflight_writes: int = 8,
        prefetch: Optional[PrefetchConfig] = None,
        _index: int = 0,
    ) -> None:
        self.cluster = cluster
        #: this session's traffic only; the cluster's ``stats`` aggregates all
        self.stats = TrafficStats()
        #: private tier: write-through under the session's own assigned
        #: versions (a writer's re-reads are RAM hits before anyone else can
        #: even see the version); ALSO serves as the read-fill cache when the
        #: cluster runs without a shared tier
        self.cache: Optional[PageCache] = (
            PageCache(cache_bytes) if cache_bytes else None
        )
        #: pick the least-read-loaded replica per page instead of always the
        #: primary (the knob the skew-read benchmark flips)
        self.replica_spread = replica_spread
        #: run writes with the pre-pipeline full barriers + per-page copies
        #: (the A/B baseline for the ``sync-write`` benchmark mode)
        self.sync_write = sync_write
        #: run reads with the pre-pipeline phased plane — the full metadata
        #: traversal completes before the first ``get_pages`` RPC leaves the
        #: node (the A/B baseline for the ``sync-read`` benchmark mode)
        self.sync_read = sync_read
        #: optional stride readahead into the shared tier (off by default)
        self.prefetcher: Optional[StridePrefetcher] = (
            StridePrefetcher(self, prefetch) if prefetch is not None else None
        )
        #: bounded in-flight window for :meth:`BlobHandle.write_async`
        self.max_inflight_writes = max_inflight_writes
        self._write_window = threading.BoundedSemaphore(max_inflight_writes)
        self._writer_pool: Optional[ThreadPoolExecutor] = None
        self._writer_pool_lock = make_lock("Session._writer_pool_lock")
        self._async_lock = make_lock("Session._async_lock")
        self._async_writes: List[Future] = []
        #: assigned-but-unreported versions per blob (guarded by
        #: ``_async_lock``): a node death mid-write leaves these wedging
        #: in-order publication, and the repair service's writer-recovery
        #: path (``RepairService.recover_writers``) abandons them
        self._inflight_versions: Dict[int, Set[int]] = {}
        self._pool = cluster._pool
        # per-session stream, DISTINCT per session: N sessions seeded alike
        # would sample identical replica pairs in lockstep and re-herd the
        # very hot pages replica spreading exists to fan out
        self._rng = random.Random(0xB10B + 0x9E3779B1 * _index)
        self._closed = False

    # -- handles ---------------------------------------------------------------
    def open(self, blob_id: int) -> "BlobHandle":
        return BlobHandle(self, blob_id)

    def create(self, size_bytes: int, page_size: int) -> "BlobHandle":
        """ALLOC a fresh blob on the cluster and open it in this session."""
        return self.open(self.cluster.alloc(size_bytes, page_size))

    # -- stats plumbing --------------------------------------------------------
    def _record_data(
        self, dest: int, n_messages: int, n_bytes: int, read: bool = False
    ) -> None:
        self.stats.record_data(dest, n_messages, n_bytes, read=read)
        self.cluster.stats.record_data(dest, n_messages, n_bytes, read=read)

    def _record_cache(self, hits: int, misses: int) -> None:
        self.stats.record_cache(hits=hits, misses=misses)
        self.cluster.stats.record_cache(hits=hits, misses=misses)

    def _record_retry(self, n: int = 1) -> None:
        self.stats.record_retry(n)
        self.cluster.stats.record_retry(n)

    def _record_fallback(self, n: int = 1) -> None:
        self.stats.record_fallback(n)
        self.cluster.stats.record_fallback(n)

    def _record_degraded(self, n: int = 1) -> None:
        self.stats.record_degraded_read(n)
        self.cluster.stats.record_degraded_read(n)

    def _record_checksum_failure(self, n: int = 1) -> None:
        self.stats.record_checksum_failure(n)
        self.cluster.stats.record_checksum_failure(n)

    @property
    def cache_hit_rate(self) -> float:
        h, m = self.stats.cache_hits, self.stats.cache_misses
        return h / (h + m) if h + m else 0.0

    # -- WRITE plane -----------------------------------------------------------
    def _writev(
        self,
        blob_id: int,
        patches: Sequence[Tuple[int, np.ndarray]],
        coalesce_meta: bool = False,
    ) -> List[int]:
        """Vectored WRITE (see :meth:`BlobHandle.writev` for semantics and
        the zero-copy buffer-surrender contract). ``coalesce_meta`` routes
        the node store through the DHT's group-commit path so concurrent
        small writes (the ``write_async`` window) share one shard round."""
        self.cluster._check_node_up()
        vm = self.cluster.version_manager
        total_pages, page_size = vm.blob_info(blob_id)
        sync = self.sync_write
        # pass 1: validate and normalize every patch — no side effects yet,
        # so a bad later patch cannot leave earlier buffers frozen
        bufs: List[np.ndarray] = []
        spans: List[Tuple[int, int]] = []  # (page_offset, n_pages) per patch
        for offset_bytes, buffer in patches:
            src = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
            if offset_bytes % page_size or src.size % page_size:
                raise ValueError("WRITE must be page-aligned (paper §II)")
            n_pages = src.size // page_size
            if n_pages == 0:
                raise ValueError("empty write")
            bufs.append(src)
            spans.append((offset_bytes // page_size, n_pages))
        if not bufs:
            return []
        # pass 2 (pipelined only; the sync baseline copies every page anyway):
        # make each source immutable before any view of it is handed out.
        # Zero-copy is only safe when freezing the array that OWNS the memory
        # actually cuts off future writes — i.e. the caller passed the owning
        # array itself (or our normalization already copied). A view of some
        # larger writable array cannot be protected by freezing (writes
        # through the base would still mutate the stored pages), so that case
        # falls back to ONE bulk copy per patch — never a per-page copy.
        if not sync:
            for i, (src, (_, buffer)) in enumerate(zip(bufs, patches)):
                root = src
                while isinstance(root.base, np.ndarray):
                    root = root.base
                if root.flags.writeable:
                    caller_root = buffer
                    while isinstance(caller_root, np.ndarray) and isinstance(
                        caller_root.base, np.ndarray
                    ):
                        caller_root = caller_root.base
                    owns = root is not caller_root or (
                        isinstance(buffer, np.ndarray) and buffer.base is None
                    )
                    if owns:
                        root.flags.writeable = False
                    else:
                        src = bufs[i] = src.copy()
                        src.flags.writeable = False
                ro = src.view()
                ro.flags.writeable = False
                bufs[i] = ro

        provider_manager = self.cluster.provider_manager
        metadata = self.cluster.metadata

        # (1) placements for every fresh page of every patch, in one call
        placements = provider_manager.allocate(sum(n for _, n in spans))

        by_provider: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        per_patch: List[List[Tuple[PageRef, Tuple[PageRef, ...]]]] = []
        #: per patch, the page arrays actually handed to the store (views in
        #: the pipelined path, copies in the sync baseline) — the write-through
        #: cache must reference these, never a possibly-writable source
        stored_pages: List[List[np.ndarray]] = []
        versions: List[int] = []
        node_keys: List[NodeKey] = []
        data_futures: List[Future] = []
        meta_futures: List[Future] = []
        try:
            cursor = 0
            #: per patch, per page: the integrity checksum stamped onto the
            #: leaf — computed HERE, at freeze time, so it attests to exactly
            #: the immutable bytes handed to the store
            checksums: List[List[int]] = []
            for src, (_, n_pages) in zip(bufs, spans):
                mine = placements[cursor : cursor + n_pages]
                cursor += n_pages
                per_patch.append(mine)
                pages: List[np.ndarray] = []
                sums: List[int] = []
                for i, (primary, replicas) in enumerate(mine):
                    page = src[i * page_size : (i + 1) * page_size]
                    if sync:
                        page = page.copy()  # pre-pipeline baseline: defensive copy
                    pages.append(page)
                    sums.append(page_checksum(page))
                    for pid, key in (primary,) + replicas:
                        by_provider.setdefault(pid, []).append((key, page))
                stored_pages.append(pages)
                checksums.append(sums)

            # (2) LAUNCH the aggregated per-provider puts; the pipeline only
            #     joins them at the end (sync baseline: full barrier here)
            data_pids = list(by_provider)
            data_futures = [
                self._pool.submit(self._put_batch, pid, items)
                for pid, items in by_provider.items()
            ]
            if sync:
                for f in data_futures:
                    f.result()

            # (3) version numbers + border links for ALL patches under ONE
            #     manager lock acquisition (the only serialized step) — this
            #     does not depend on data-put completion, so it runs while
            #     the pages are still in flight
            assigned = vm.assign_versions(blob_id, spans)
            versions = [v for v, _ in assigned]
            with self._async_lock:
                self._inflight_versions.setdefault(blob_id, set()).update(
                    versions
                )

            # (4) weave every patch's tree while the data puts are still in
            #     flight, then LAUNCH one aggregated node put per shard
            #     (paper §V.A aggregation across the whole writev); the sync
            #     baseline runs the same aggregated put behind a barrier
            all_nodes: List[TreeNode] = []
            for (page_offset, n_pages), mine, sums, (version, links) in zip(
                spans, per_patch, checksums, assigned
            ):
                all_nodes.extend(
                    build_write_tree(
                        blob_id, version, total_pages, page_offset, n_pages,
                        mine, links, leaf_checksums=sums,
                    )
                )
            node_keys.extend(node.key for node in all_nodes)
            if sync:
                metadata.put_nodes(all_nodes)
            elif coalesce_meta:
                # cross-writev coalescing: writes streaming through the async
                # window merge their node batches into one shard round
                meta_futures.extend(metadata.put_nodes_coalesced(all_nodes))
            else:
                meta_futures.extend(metadata.put_nodes_async(all_nodes))

            # join: every page and node must be durable before success. The
            # metadata futures join FIRST so that when a data batch has to be
            # re-placed onto a healthy provider, no stale in-flight leaf put
            # can overwrite the corrected refs we write below.
            for f in meta_futures:
                err = f.exception()
                if err is not None:
                    raise err
            failed_batches: List[Tuple[int, BaseException]] = []
            for pid, f in zip(data_pids, data_futures):
                err = f.exception()
                if err is None:
                    continue
                if sync or not isinstance(err, (ProviderFailed, KeyError)):
                    raise err  # sync baseline keeps abort-on-failure
                failed_batches.append((pid, err))
            if failed_batches:
                # self-healing: move the dead provider's pages to healthy
                # nodes mid-flight instead of aborting the whole writev;
                # raises (→ abort path) only when no healthy target remains
                self._replace_failed_batches(
                    blob_id, failed_batches, by_provider, placements, all_nodes
                )

            # (5) report success (one lock for the batch) → in-order publish
            vm.report_successes(blob_id, versions)
            self._untrack_inflight(blob_id, versions)
        except VersionAbandoned:
            # writer recovery (a federated node-death verdict) withdrew
            # these versions mid-flight and owns their wreckage — abandon
            # again would be a no-op, and cleaning up here would double-
            # release what the recovery scrub already released. Just
            # quiesce the in-flight puts and surface the failure.
            for f in data_futures + meta_futures:
                f.exception()
            self._untrack_inflight(blob_id, versions)
            raise
        except BaseException:
            # NOTE: frozen sources stay frozen — a concurrent write may
            # already hold zero-copy views of the same root, so restoring
            # writability here would let the caller mutate ITS published
            # pages through the shared memory
            self._abort_writev(
                blob_id, versions, placements, by_provider, node_keys,
                data_futures, meta_futures,
            )
            self._untrack_inflight(blob_id, versions)
            raise

        # write-through into the PRIVATE tier only: the just-stored pages are
        # already immutable, so this session's re-reads of these versions come
        # straight from RAM — but the versions may not have published yet, and
        # the shared tier must never hold a page another session could not
        # also fetch from the providers after frontier validation
        if self.cache is not None:
            items: List[Tuple[Tuple[int, int, int], np.ndarray]] = []
            for pages, (page_offset, _), version in zip(
                stored_pages, spans, versions
            ):
                for i, page in enumerate(pages):
                    items.append(((blob_id, version, page_offset + i), page))
            self.cache.put_many(items)
        return versions

    def _put_batch(self, pid: int, items: List[Tuple[int, np.ndarray]]) -> None:
        """One aggregated data put, retried per :class:`RetryPolicy`.

        Every failed attempt feeds the health machine; retries stop early
        once the target is declared dead (the writev join will re-place the
        batch on a healthy provider instead). ``KeyError`` (the provider was
        deregistered mid-flight) is not retried — the id will never come
        back. Backoff runs on a pool worker, never under a lock."""
        pm = self.cluster.provider_manager
        policy = self.cluster.retry_policy
        attempts = max(policy.max_attempts, 1)
        for attempt in range(attempts):
            try:
                pm.get_provider(pid).put_pages(items)
            except ProviderFailed:
                pm.note_failure(pid)
                if attempt + 1 < attempts and pid not in pm.dead_providers():
                    self._record_retry()
                    policy.backoff(attempt)
                    continue
                raise
            pm.note_success(pid)
            self._record_data(pid, len(items), sum(p.nbytes for _, p in items))
            return

    def _replace_failed_batches(
        self,
        blob_id: int,
        failed_batches: List[Tuple[int, BaseException]],
        by_provider: Dict[int, List[Tuple[int, np.ndarray]]],
        placements: List[Tuple[PageRef, Tuple[PageRef, ...]]],
        all_nodes: List[TreeNode],
    ) -> None:
        """Mid-flight write repair: a data batch whose provider died (or was
        deregistered) after placement gets re-put onto healthy providers, and
        the writev completes instead of aborting.

        Works per failed batch, transactionally: either every item of the
        batch lands on a healthy target (then the bookkeeping — load credit,
        ``by_provider``, ``placements``, the woven leaves — is swung over to
        the new refs), or the partial moves are undone and the original
        error is re-raised so the normal abort path runs on *consistent*
        state. Leaf corrections are plain re-puts of still-unpublished keys;
        the metadata futures joined before this runs, so no stale in-flight
        put can overwrite a corrected leaf."""
        pm = self.cluster.provider_manager
        metadata = self.cluster.metadata
        failed_pids = {pid for pid, _ in failed_batches}
        moved: Dict[PageRef, PageRef] = {}
        for pid, original_err in failed_batches:
            items = by_provider[pid]
            # replica sets must stay on distinct providers: for each page key,
            # know who else already holds a copy
            holders: Dict[int, Set[int]] = defaultdict(set)
            for other_pid, other_items in by_provider.items():
                if other_pid != pid:
                    for key, _ in other_items:
                        holders[key].add(other_pid)
            placed: List[Tuple[int, int, np.ndarray]] = []  # (target, key, page)
            try:
                for key, page in items:
                    tried: Set[int] = set()
                    while True:
                        target = pm.least_loaded(
                            exclude=tuple(holders[key] | failed_pids | tried)
                        )
                        if target is None:
                            raise original_err  # no healthy target → abort
                        try:
                            pm.get_provider(target).put_pages([(key, page)])
                        except (ProviderFailed, KeyError):
                            pm.note_failure(target)
                            tried.add(target)
                            continue
                        pm.note_success(target)
                        pm.add_load(target, 1)
                        placed.append((target, key, page))
                        moved[(pid, key)] = (target, key)
                        self._record_data(target, 1, page.nbytes)
                        break
            except BaseException:
                # undo THIS batch's partial moves; earlier batches already
                # committed their bookkeeping, so abort cleanup stays exact
                for target, key, _ in placed:
                    try:
                        pm.get_provider(target).delete_pages([key])
                    except (ProviderFailed, KeyError):
                        pass
                    moved.pop((pid, key), None)
                pm.release([(target, key) for target, key, _ in placed])
                raise
            # commit: the dead provider's load credit moves to the new holders
            pm.release([(pid, key) for key, _ in items])
            del by_provider[pid]
            for target, key, page in placed:
                by_provider.setdefault(target, []).append((key, page))
            self._record_retry(len(items))
        # rewrite affected leaves with the corrected refs
        corrected = [
            dataclasses.replace(
                node,
                page=moved.get(node.page, node.page),
                replicas=tuple(moved.get(r, r) for r in node.replicas),
            )
            for node in all_nodes
            if node.is_leaf
            and (node.page in moved or any(r in moved for r in node.replicas))
        ]
        if corrected:
            metadata.put_nodes(corrected)
        # swing placements to the new refs so a LATER failure's abort path
        # deletes/releases what is actually stored now
        for i, (primary, replicas) in enumerate(placements):
            if primary in moved or any(r in moved for r in replicas):
                placements[i] = (
                    moved.get(primary, primary),
                    tuple(moved.get(r, r) for r in replicas),
                )

    def _abort_writev(
        self,
        blob_id: int,
        versions: List[int],
        placements: List[Tuple[PageRef, Tuple[PageRef, ...]]],
        by_provider: Dict[int, List[Tuple[int, np.ndarray]]],
        node_keys: List[NodeKey],
        data_futures: List[Future],
        meta_futures: List[Future],
    ) -> None:
        """Failure cleanup for a mid-flight ``writev``: without this, the
        placement load heap keeps phantom load, stored pages and nodes of the
        doomed versions leak forever, and in-order publication wedges behind
        versions that will never report success.

        The doomed versions are withdrawn first; what happens to their
        stored wreckage depends on how :meth:`VersionManager.abandon`
        resolved them. Fully *erased* versions (no concurrent writer assigned
        after them) are scrubbed: pages deleted, nodes deleted, placement
        credits released. Versions that became publication *holes* are left
        in place instead — a later writer may already have woven border links
        into their trees, so deleting whatever did land would turn that
        writer's published version unreadable; the wreckage stays until
        :meth:`Cluster.gc` collects it (which also returns the load
        credit), the same stance taken for orphans on a down provider."""
        provider_manager = self.cluster.provider_manager
        for f in data_futures + meta_futures:
            f.exception()  # quiesce: no put may still be in flight
        if versions:
            holes = self.cluster.version_manager.abandon(blob_id, versions)
            if holes:
                return  # leak to GC: later versions may reference the nodes
        for pid, items in by_provider.items():
            try:  # best-effort: a down provider keeps its orphans until GC
                provider_manager.get_provider(pid).delete_pages(
                    [key for key, _ in items]
                )
            except (ProviderFailed, KeyError):
                pass
        try:
            self.cluster.metadata.delete_nodes(node_keys)
        except ProviderFailed:
            pass
        provider_manager.release(
            [ref for primary, replicas in placements for ref in (primary,) + replicas]
        )

    def _untrack_inflight(self, blob_id: int, versions: Sequence[int]) -> None:
        with self._async_lock:
            mine = self._inflight_versions.get(blob_id)
            if mine is None:
                return
            mine.difference_update(versions)
            if not mine:
                del self._inflight_versions[blob_id]

    def inflight_versions(self) -> Dict[int, Set[int]]:
        """Snapshot of this session's assigned-but-unreported versions —
        what writer recovery must abandon when the session's node dies
        mid-write (in-order publication would otherwise wedge forever)."""
        with self._async_lock:
            return {b: set(vs) for b, vs in self._inflight_versions.items()}

    # -- asynchronous write streaming ------------------------------------------
    def _write_async(
        self, blob_id: int, buffer: np.ndarray, offset_bytes: int
    ) -> "Future[int]":
        if self._closed:
            # a closed session's writer pool is already shut down and the
            # cluster no longer tracks the session (GC would skip its cache);
            # silently resurrecting the pool here would leak its threads
            raise RuntimeError("write_async on a closed session")
        self._write_window.acquire()
        try:
            future = self._writers().submit(
                self._windowed_write, blob_id, buffer, offset_bytes
            )
        except BaseException:
            self._write_window.release()
            raise
        with self._async_lock:
            # prune successfully-completed futures so a long-running streamer
            # that joins its own returned futures (never calls flush) does
            # not accumulate them forever; FAILED futures are kept until
            # flush()/close() so their errors cannot vanish unobserved
            self._async_writes = [
                f for f in self._async_writes
                # done() guards the exception() call: it cannot block here
                if not f.done() or f.exception() is not None  # lint: allow(blocking-under-lock)
            ]
            self._async_writes.append(future)
        return future

    def _writers(self) -> ThreadPoolExecutor:
        with self._writer_pool_lock:
            if self._writer_pool is None:
                self._writer_pool = ThreadPoolExecutor(
                    max_workers=self.max_inflight_writes
                )
            return self._writer_pool

    def _windowed_write(
        self, blob_id: int, buffer: np.ndarray, offset_bytes: int
    ) -> int:
        try:
            # async-window writes coalesce their metadata: several small
            # writes in flight at once share ONE aggregated shard round
            return self._writev(
                blob_id, [(offset_bytes, buffer)],
                coalesce_meta=not self.sync_write,
            )[0]
        finally:
            self._write_window.release()

    def flush(self) -> List[int]:
        """Join every outstanding ``write_async`` of this session —
        SESSION-GLOBAL: it drains the whole window, including writes queued
        by other threads sharing this session (a multi-writer client should
        instead join the futures ``write_async`` returned to it). Returns the
        versions of the writes still tracked by the window (writes that
        completed and were already pruned are not re-reported) and re-raises
        the first failure."""
        with self._async_lock:
            futures, self._async_writes = self._async_writes, []
        versions: List[int] = []
        first_err: Optional[BaseException] = None
        for f in futures:
            try:
                versions.append(f.result())
            except BaseException as err:  # keep joining; surface the first
                if first_err is None:
                    first_err = err
        if first_err is not None:
            raise first_err
        return versions

    # -- READ plane --------------------------------------------------------------
    def read_pages(
        self,
        blob_id: int,
        version: int,
        pages: Sequence[int],
        pinned: bool = False,
    ) -> List[np.ndarray]:
        """Gather whole pages of one published ``version`` in a single
        vectored read — the serving plane's page-table → readv-plan surface.
        Full-page segments come back as zero-copy views of cached pages.

        ``pinned=True`` is the caller's attestation that ``version`` is held
        by a snapshot pin it owns (taken via :meth:`Cluster.pin_published`,
        which already validated the publish frontier); the per-call frontier
        check is then skipped, exactly like :class:`Snapshot` re-reads.
        Without it the version is validated here, so an unpublished version
        can never be read either way."""
        vm = self.cluster.version_manager
        if pinned:
            total_pages, page_size = vm.blob_info(blob_id)
        else:
            total_pages, page_size, version, _ = vm.resolve_read_version(
                blob_id, version
            )
        segments = [(p * page_size, page_size) for p in pages]
        return self._readv(blob_id, version, segments, total_pages, page_size)

    def _readv(
        self,
        blob_id: int,
        version: int,
        segments: Sequence[Tuple[int, int]],
        total_pages: int,
        page_size: int,
    ) -> List[np.ndarray]:
        """``readv`` body with the version-manager state already resolved —
        the serialized actor is consulted exactly once per public call (and
        not at all for :class:`Snapshot` re-reads).

        The miss path is a *streaming pipeline*, symmetric with the write
        plane: as the level-synchronous metadata traversal resolves leaves
        (per shard, as each shard's RPC of a level completes), the
        per-provider ``get_pages`` futures launch immediately on the cluster
        pool — data transfer overlaps the remaining metadata rounds, with
        ONE join before assembly. ``sync_read=True`` keeps the phased
        baseline: the full traversal completes before the first page fetch."""
        self.cluster._check_node_up()
        # clamp segments; collect the deduplicated union of needed pages
        total_bytes = total_pages * page_size
        clamped: List[Tuple[int, int]] = []
        needed: Set[int] = set()
        for offset, size in segments:
            if offset < 0 or size < 0:
                raise ValueError(f"negative read offset/size ({offset}, {size})")
            if size == 0:
                clamped.append((offset, 0))
                continue
            if offset >= total_bytes:
                raise ValueError(
                    f"read at offset {offset} out of range (blob is {total_bytes} bytes)"
                )
            size = min(size, total_bytes - offset)  # clamp to blob end
            clamped.append((offset, size))
            first_page = offset // page_size
            last_page = min(-(-(offset + size) // page_size), total_pages)
            needed.update(range(first_page, last_page))

        # adaptive readahead: feed the stride detector BEFORE this read's own
        # fetch, so the readahead it may issue (for pages beyond this read)
        # overlaps the demand traversal below. The observed version is the
        # resolved published version, so prefetch can never cross the
        # publish frontier.
        if self.prefetcher is not None and needed:
            self.prefetcher.observe(
                blob_id, version, min(needed), max(needed) + 1,
                total_pages, page_size,
            )

        # cache phase. Tier order: the private cache first (it may hold this
        # session's own write-through pages), then the shared tier, which
        # also provides cross-session single-flight — exactly one reader on
        # the whole node becomes the fetch leader for each missing page. The
        # version was already validated against the publish frontier, so
        # everything that enters the shared tier here is published data.
        pages: Dict[int, Optional[np.ndarray]] = {}
        if self.cluster.caches_servable():
            private = self.cache
            shared = self.cluster.shared_cache
        else:
            # FENCED (federated lease lapsed): no cache tier may serve or be
            # filled — the whole read goes through to the providers, which is
            # always correct because federated GC never reclaims a version a
            # live node still needs
            private = None
            shared = None
        flight_cache = shared if shared is not None else private
        owned: List[int] = []
        waits: Dict[Tuple[int, int, int], object] = {}
        if needed:
            keys = [(blob_id, version, p) for p in sorted(needed)]
            hits = 0
            if shared is not None and private is not None:
                got = private.get_many(keys)
                pages.update({key[2]: pg for key, pg in got.items()})
                hits += len(got)
                keys = [k for k in keys if k not in got]
            if flight_cache is not None:
                plan = flight_cache.plan(keys, record=False)
                pages.update({key[2]: page for key, page in plan.hits.items()})
                hits += len(plan.hits)
                owned = sorted(key[2] for key in plan.owned)
                waits = plan.waits
                self._record_cache(hits, len(owned) + len(waits))
            else:
                owned = sorted(key[2] for key in keys)

        if owned:
            fulfilled: Set[int] = set()
            stream = _PageFetchStream(self, page_size)
            redirect = self._read_redirect(blob_id)
            try:
                if self.sync_read:
                    # phased baseline: the traversal runs to completion, THEN
                    # the leaves are fetched (one aggregated RPC per provider)
                    leaves = traverse_batch(
                        self.cluster.metadata.get_nodes, blob_id, version,
                        total_pages, _merge_ranges(owned), redirect=redirect,
                    )
                    stream.submit(leaves)
                else:
                    # (2)+(3) overlapped: per-shard partial results stream
                    # get_pages futures into flight mid-level; the per-level
                    # on_leaves emission is the catch-all for get_nodes
                    # implementations that do not stream (stream.submit
                    # dedups, so doubly delivered leaves fetch once)
                    def _streaming_get_nodes(keys):
                        return self.cluster.metadata.get_nodes(
                            keys, on_partial=stream.submit_partial
                        )

                    leaves = traverse_batch(
                        _streaming_get_nodes, blob_id, version, total_pages,
                        _merge_ranges(owned), on_leaves=stream.submit,
                        redirect=redirect,
                    )
                    # implicit-zero pages resolve in the traversal, not the
                    # data plane — record them with the stream's results
                    stream.submit(
                        {p: None for p, leaf in leaves.items() if leaf is None}
                    )
                # the ONE join of the read pipeline: every launched fetch
                # lands (with per-page replica fallback) before assembly
                fetched = stream.join()
                for p, page in fetched.items():
                    pages[p] = page
                    if flight_cache is not None:
                        # zero pages share one buffer — charge them the LRU
                        # slot, not a full page, so repeat sparse reads skip
                        # the metadata walk without evicting real pages
                        flight_cache.fulfill(
                            (blob_id, version, p),
                            page if page is not None else _zero_page(page_size),
                            charge=None if page is not None else ZERO_PAGE_CHARGE,
                        )
                        fulfilled.add(p)
            except BaseException as err:
                stream.quiesce()  # no fetch may still be in flight
                if flight_cache is not None:
                    for p in owned:
                        if p not in fulfilled:
                            flight_cache.abort((blob_id, version, p), err)
                raise

        # follower phase: collect pages fetched by concurrent leaders
        for key, flight in waits.items():
            pages[key[2]] = flight_cache.wait(key, flight)  # type: ignore[union-attr, arg-type]

        # assemble per-segment outputs from the shared page map: a segment
        # covering exactly one whole page is served as a zero-copy read-only
        # view of that page; an aligned multi-page segment is one C-level
        # concatenate of the page views (no per-page Python copy loop); the
        # unaligned rest goes into an UNinitialized buffer with explicit
        # zero-fill only where a page is implicitly zero — never a full
        # zero-fill that every byte then overwrites
        outs: List[np.ndarray] = []
        for offset, size in clamped:
            if size == 0:
                outs.append(np.empty(0, dtype=np.uint8))
                continue
            if size == page_size and offset % page_size == 0:
                page = pages.get(offset // page_size)
                outs.append(page if page is not None else _zero_page(page_size))
                continue
            first = offset // page_size
            last = -(-(offset + size) // page_size)
            if offset % page_size == 0 and size % page_size == 0:
                zero = _zero_page(page_size)
                parts = [pages.get(p) for p in range(first, last)]
                outs.append(np.concatenate(
                    [pg if pg is not None else zero for pg in parts]
                ))
                continue
            out = np.empty(size, dtype=np.uint8)
            for p in range(first, last):
                page = pages.get(p)
                page_lo = p * page_size
                a = max(offset, page_lo)
                b = min(offset + size, page_lo + page_size)
                if page is None:
                    out[a - offset : b - offset] = 0  # implicit zero page
                else:
                    out[a - offset : b - offset] = page[a - page_lo : b - page_lo]
            outs.append(out)
        return outs

    def _read_redirect(self, blob_id: int) -> Optional[Callable[[int, int, int], int]]:
        """Dangling-link resolver for tree traversals of ``blob_id``.

        A writer that aborted mid-flight may have become a publication
        *hole*: a later published version can carry border links into trees
        the hole never stored (the write-plane leak the metadata scrub
        eventually rewrites). The returned hook redirects any link into an
        aborted version to the newest surviving version covering the same
        segment — such a node always exists, because every stored node of a
        version covers a canonical segment intersecting that version's
        written interval. Returns ``None`` (zero overhead) when the blob has
        no abandoned versions."""
        vm = self.cluster.version_manager
        aborted = vm.aborted_view(blob_id)
        if not aborted:
            return None

        def redirect(version: int, offset: int, size: int) -> int:
            if version not in aborted:
                return version
            return vm.redirect_read_link(blob_id, version, offset, size)

        return redirect

    def _choose_ref(
        self, leaf: TreeNode, read_load: Dict[int, int], page_size: int
    ) -> PageRef:
        """Pick which replica serves this page via power-of-two random
        choices: sample two replicas, take the one with less read traffic so
        far, charging ``read_load`` tentatively so one batch also spreads.
        The random sampling is what prevents the herd effect — a
        deterministic global minimum sends every concurrent client to the
        same momentarily-idle provider, re-serializing the hot page there."""
        refs = leaf.all_page_refs()
        a, b = self._rng.sample(range(len(refs)), 2)
        pid, key = min(
            refs[a], refs[b], key=lambda r: read_load.get(r[0], 0)
        )
        read_load[pid] = read_load.get(pid, 0) + page_size
        return pid, key

    def _fetch_pages(
        self, leaves: Dict[int, Optional[TreeNode]], page_size: int
    ) -> Dict[int, Optional[np.ndarray]]:
        """Fetch all leaf pages in one shot: one aggregated RPC per serving
        provider (in parallel), per-page replica fallback if a provider batch
        fails. This is the phased entry point (``sync_read`` baseline,
        background prefetch fills); the streaming read plane drives the same
        :class:`_PageFetchStream` incrementally instead."""
        stream = _PageFetchStream(self, page_size)
        stream.submit(leaves)
        return stream.join()

    def _get_batch(
        self, pid: int, items: List[Tuple[int, int, TreeNode]]
    ) -> Optional[Dict[int, np.ndarray]]:
        """One aggregated ``get_pages`` RPC to provider ``pid``; ``None`` on
        provider failure (the stream's join falls back per page). Failures
        feed the health machine — enough of them within the decay window
        marks the source suspect, then dead (triggering background repair)."""
        pm = self.cluster.provider_manager
        try:
            provider = pm.get_provider(pid)
            fetched = provider.get_pages([key for _, key, _ in items])
        except ProviderFailed:
            pm.note_failure(pid)
            return None  # provider down: caller falls back per page
        except KeyError:
            return None  # deregistered: nothing to mark
        self._record_data(
            pid, len(items), sum(pg.nbytes for pg in fetched), read=True
        )
        # end-to-end integrity: verify every page against the checksum its
        # leaf carries; a mismatch is a provider failure, not a crash — the
        # bad page is simply absent from the result, and the stream's join
        # falls back to a replica and repairs the corrupt copy
        good: Dict[int, np.ndarray] = {}
        corrupt = 0
        for (p, _, leaf), pg in zip(items, fetched):
            if leaf.checksum is not None and page_checksum(pg) != leaf.checksum:
                corrupt += 1
                continue
            good[p] = pg
        if corrupt:
            self._record_checksum_failure(corrupt)
            pm.note_failure(pid)
        else:
            pm.note_success(pid)
        return good

    def _prefetch_fill(
        self,
        blob_id: int,
        version: int,
        prefetch_pages: Sequence[int],
        total_pages: int,
        page_size: int,
    ) -> int:
        """Best-effort background fill of ``prefetch_pages`` of a *published*
        ``version`` into the session's fill tier (the cluster's shared tier
        when present — so one session's readahead warms every session on the
        node). Used by the stride prefetcher and the watch warmer; runs off
        the read path (aux pool / warmer thread).

        Coherence is the same argument as any read: the version was resolved
        against the publish frontier by whoever triggered the fill, fills go
        through the cache's single-flight plan (``record=False`` — a
        prefetch miss must not distort any session's demand hit rate), and
        every owned key is fulfilled or aborted even on failure, so demand
        readers waiting as followers never hang. Returns pages filled."""
        if not self.cluster.caches_servable():
            return 0  # fenced node: background fills must not repopulate
        cache = (
            self.cluster.shared_cache
            if self.cluster.shared_cache is not None
            else self.cache
        )
        if cache is None:
            return 0
        plan = cache.plan(
            [(blob_id, version, p) for p in prefetch_pages], record=False
        )
        owned = sorted(key[2] for key in plan.owned)
        if not owned:
            return 0
        done: Set[int] = set()
        try:
            leaves = traverse_batch(
                self.cluster.metadata.get_nodes, blob_id, version, total_pages,
                _merge_ranges(owned), redirect=self._read_redirect(blob_id),
            )
            fetched = self._fetch_pages(leaves, page_size)
            for p in owned:
                page = fetched.get(p)
                cache.fulfill(
                    (blob_id, version, p),
                    page if page is not None else _zero_page(page_size),
                    charge=None if page is not None else ZERO_PAGE_CHARGE,
                )
                done.add(p)
        except BaseException as err:
            for p in owned:
                if p not in done:
                    cache.abort((blob_id, version, p), err)
        return len(done)

    def _fetch_single(
        self,
        page_index: int,
        leaf: TreeNode,
        skip_pid: Optional[int] = None,
        repair_refs: Sequence[PageRef] = (),
    ) -> np.ndarray:
        """Per-page replica fallback with bounded retry rounds: every replica
        is tried once per round (each failure feeding the health machine);
        between rounds the retry policy backs off — a transient blip on ALL
        replicas still completes, a truly lost page fails after
        ``max_attempts`` rounds.

        Integrity: a fetched page whose checksum mismatches the leaf's is
        treated as a failed (non-retryable) copy — the fallback continues to
        the other replicas, and once a verified-good page is in hand every
        corrupt copy observed (plus any the caller already detected, via
        ``repair_refs``) is overwritten in place with the good bytes."""
        pm = self.cluster.provider_manager
        policy = self.cluster.retry_policy
        refs = [r for r in leaf.all_page_refs() if r[0] != skip_pid]
        refs = list(refs or leaf.all_page_refs())
        corrupt: List[PageRef] = list(repair_refs)
        last_err: Optional[Exception] = None
        for attempt in range(max(policy.max_attempts, 1)):
            if attempt:
                self._record_retry()
                policy.backoff(attempt - 1)
            retryable = False
            for pid, key in refs:
                if (pid, key) in corrupt:
                    continue  # known-bad copy: only a repair target now
                try:
                    page = pm.get_provider(pid).get_page(key)
                except ProviderFailed as err:
                    pm.note_failure(pid)
                    last_err = err
                    retryable = True  # the provider may come back
                    continue
                except KeyError as err:
                    last_err = err  # missing page/provider: will not heal
                    continue
                if (
                    leaf.checksum is not None
                    and page_checksum(page) != leaf.checksum
                ):
                    # silent corruption: never return the bad bytes; the
                    # copy will not heal by retrying, so fall through to
                    # the remaining replicas and remember it for repair
                    self._record_checksum_failure()
                    pm.note_failure(pid)
                    corrupt.append((pid, key))
                    last_err = ProviderFailed(
                        f"page {page_index} checksum mismatch at provider {pid}"
                    )
                    continue
                pm.note_success(pid)
                self._record_data(pid, 1, page.nbytes, read=True)
                for ref in corrupt:
                    self._repair_corrupt_copy(ref, page)
                return page
            if not retryable:
                break
        raise last_err if last_err else KeyError(f"page {page_index} unavailable")

    def _repair_corrupt_copy(self, ref: PageRef, page: np.ndarray) -> None:
        """Overwrite a checksum-failed stored copy with verified-good bytes.
        Best-effort: page CONTENT under a key is immutable, so rewriting a
        corrupt copy restores the published data rather than mutating it (the
        same sanctioned-re-put argument the repair service relies on)."""
        pm = self.cluster.provider_manager
        pid, key = ref
        try:
            pm.get_provider(pid).put_pages([(key, page)])
        except (ProviderFailed, KeyError):
            return  # the copy stays bad; reads keep falling back around it
        self.stats.record_repair(1)
        self.cluster.stats.record_repair(1)

    # -- lifecycle ---------------------------------------------------------------
    def close(self) -> None:
        """Quiesce the async write window and detach from the cluster.
        Errors of still-outstanding async writes are the caller's to observe
        via ``flush()``/the returned futures, not ``close()``."""
        if self._closed:
            return
        self._closed = True
        with self._async_lock:
            futures, self._async_writes = self._async_writes, []
        for f in futures:
            f.exception()
        # detach the pool under the lock, shut it down OUTSIDE it: a writer
        # task that touches the session while close() waits for it would
        # otherwise deadlock on _writer_pool_lock
        with self._writer_pool_lock:
            pool, self._writer_pool = self._writer_pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        self.cluster._forget_session(self)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BlobHandle:
    """Fine-grain access to one blob through one session (paper §III.B).

    WRITE is the overlapped pipeline (data puts in flight while versions are
    assigned and metadata is woven; one join; in-order publication), READ is
    its symmetric streaming pipeline: private tier, then the cluster's
    shared tier with node-wide single-flight, then one level-synchronous
    metadata traversal whose resolving leaves launch aggregated per-provider
    page fetches *while the remaining metadata rounds are still in flight*
    (one join before assembly; ``sync_read`` sessions keep the phased
    baseline). Page transport is zero-copy end to end: ``writev`` freezes
    owning source buffers and hands page views to the providers; a
    full-single-page read returns a read-only view of the stored/cached
    page.
    """

    def __init__(self, session: Session, blob_id: int) -> None:
        self.session = session
        self.blob_id = blob_id
        self.total_pages, self.page_size = (
            session.cluster.version_manager.blob_info(blob_id)
        )

    @property
    def size_bytes(self) -> int:
        return self.total_pages * self.page_size

    @property
    def _vm(self) -> VersionManager:
        return self.session.cluster.version_manager

    # -- versions ---------------------------------------------------------------
    def latest_published(self) -> int:
        """Latest readable published version."""
        return self._vm.latest_published(self.blob_id)

    def wait_for_version(self, version: int, timeout: Optional[float] = None) -> bool:
        """Block until ``version`` publishes; False on timeout."""
        return self._vm.wait_published(self.blob_id, version, timeout)

    def snapshot(self) -> "Snapshot":
        """Pin the latest published version; see :class:`Snapshot`."""
        return self.at(None)

    def at(self, version: Optional[int]) -> "Snapshot":
        """Pin ``version`` (validated published and readable) for lock-free
        repeated reads; ``None`` pins the latest published version. Pinning
        serializes against :meth:`Cluster.gc` so a returned snapshot's
        version was either visible to every earlier GC pass or created after
        the pass finished — never silently collected mid-creation. (A
        snapshot of a version a *completed* GC already dropped still fails
        on first read: the pin protects the future, not the past.)"""
        cluster = self.session.cluster
        with cluster._gc_guard:
            total_pages, page_size, resolved, _ = self._vm.resolve_read_version(
                self.blob_id, version
            )
            cluster.pin_version(self.blob_id, resolved)
        return Snapshot(self, resolved, total_pages, page_size)

    def watch(self, start_version: Optional[int] = None) -> "VersionWatch":
        """Subscribe to publications of this blob: the returned
        :class:`VersionWatch` delivers every published version greater than
        ``start_version`` (default: the latest published right now), strictly
        in version order, waking on :meth:`VersionManager.wait_published`
        instead of polling."""
        if start_version is None:
            start_version = self._vm.latest_published(self.blob_id)
        return VersionWatch(self._vm, self.blob_id, start_version)

    # -- READ -------------------------------------------------------------------
    def read(
        self, offset_bytes: int, size_bytes: int, version: Optional[int] = None
    ) -> ReadResult:
        """Read ``[offset_bytes, offset_bytes+size_bytes)`` of ``version``
        (``None`` = latest published). Fails if ``version`` is unpublished,
        abandoned, or the range is fully out of bounds; a range overlapping
        the blob's end is clamped (short read). A read of exactly one whole
        page returns a read-only view of the stored/cached page (zero-copy);
        copy before mutating."""
        total_pages, page_size, resolved, latest = self._vm.resolve_read_version(
            self.blob_id, version
        )
        data = self.session._readv(
            self.blob_id, resolved, [(offset_bytes, size_bytes)],
            total_pages, page_size,
        )[0]
        return ReadResult(latest, data)

    def readv(
        self, segments: Sequence[Tuple[int, int]], version: Optional[int] = None
    ) -> List[np.ndarray]:
        """Vectored READ: fetch many ``(offset_bytes, size_bytes)`` segments
        of one version in a single batched, *streaming* pass. Pages shared
        between segments are deduplicated; cache hits skip the network
        entirely; the remaining pages cost one level-synchronous metadata
        traversal (one aggregated RPC per shard per level) whose resolving
        leaves immediately launch aggregated per-provider ``get_pages``
        fetches, overlapping data transfer with the rest of the traversal
        (``sync_read`` sessions instead finish the traversal first — the
        phased baseline). Returns one ``np.uint8`` array per segment
        (full-single-page segments are read-only zero-copy views)."""
        total_pages, page_size, resolved, _ = self._vm.resolve_read_version(
            self.blob_id, version
        )
        return self.session._readv(
            self.blob_id, resolved, segments, total_pages, page_size
        )

    # -- WRITE ------------------------------------------------------------------
    def write(self, buffer: np.ndarray, offset_bytes: int) -> int:
        """Patch the blob with ``buffer`` at ``offset_bytes``; returns the
        assigned version (published once all earlier versions publish)."""
        return self.writev([(offset_bytes, buffer)])[0]

    def writev(self, patches: Sequence[Tuple[int, np.ndarray]]) -> List[int]:
        """Vectored WRITE: apply many ``(offset_bytes, buffer)`` page-aligned
        patches. Each patch gets its own version (identical semantics to a
        loop of :meth:`write`, in patch order), but the data plane batches
        AND pipelines: one placement call, ONE aggregated ``put_pages`` RPC
        per data provider across all patches launched up front, version
        assignment and metadata weaving while those puts are in flight, and a
        single join before success is reported. Returns the assigned
        versions.

        Zero-copy hand-off: the write plane freezes each source buffer that
        owns its memory (``writeable = False``) and providers keep page-sized
        views of it; a buffer passed to ``writev`` is surrendered to the
        store for good, whether the write succeeds or fails (another
        overlapping write may already share the frozen buffer, so failure
        cannot safely hand it back). Views of larger writable arrays cannot
        be frozen and are bulk-copied once per patch instead. Caveat the
        store cannot detect: a writable view the caller created BEFORE the
        call still aliases the frozen memory — mutating through it corrupts
        published data, exactly like scribbling over an O_DIRECT buffer with
        I/O in flight."""
        return self.session._writev(self.blob_id, patches)

    def write_async(self, buffer: np.ndarray, offset_bytes: int) -> "Future[int]":
        """Queue a :meth:`write` into the session's bounded in-flight window
        and return a future of its assigned version. Blocks (backpressure)
        once ``max_inflight_writes`` writes are outstanding. Successive
        writes' pipelines overlap — a later write's pages may land before an
        earlier write's metadata — while the version manager still publishes
        strictly in assignment order. Join the window with
        :meth:`Session.flush` (or await the returned future)."""
        return self.session._write_async(self.blob_id, buffer, offset_bytes)

    def write_unaligned(self, buffer: np.ndarray, offset_bytes: int) -> int:
        """WRITE at arbitrary byte offset/size via client-side
        read-modify-write of the boundary pages (the paper's API allows
        arbitrary segments; pages are the storage granularity, so partial
        boundary pages are merged from the latest published version before
        patching). Both boundary pages are fetched in one :meth:`readv`
        call, so hot boundary pages come straight from the page cache.

        Note the concurrency caveat the paper implies: the boundary merge
        reads the LATEST version, so two concurrent unaligned writers sharing
        a boundary page serialize at page granularity like any COW system."""
        page_size = self.page_size
        buffer = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
        lo = offset_bytes // page_size * page_size
        hi = -(-(offset_bytes + buffer.size) // page_size) * page_size
        if lo == offset_bytes and hi == offset_bytes + buffer.size:
            return self.write(buffer, offset_bytes)
        merged = np.zeros(hi - lo, np.uint8)
        boundary_segs: List[Tuple[int, int]] = []
        if lo < offset_bytes:  # left boundary page
            boundary_segs.append((lo, page_size))
        if hi > offset_bytes + buffer.size:  # right boundary page
            boundary_segs.append((hi - page_size, page_size))
        boundary = self.readv(boundary_segs)
        for (seg_off, _), data in zip(boundary_segs, boundary):
            merged[seg_off - lo : seg_off - lo + page_size] = data
        merged[offset_bytes - lo : offset_bytes - lo + buffer.size] = buffer
        return self.write(merged, lo)


class Snapshot:
    """An immutable, pinned view of one published version of a blob.

    Repeated reads through a snapshot are **lock-free**: the version was
    resolved and validated once at creation, so :meth:`read`/:meth:`readv`
    never touch the version manager again — the serialized actor costs zero
    on the snapshot re-read path (the supernovae detector differencing the
    same two sky versions window by window). The pinned version is also
    protected from :meth:`Cluster.gc` until :meth:`release` (or context-
    manager exit): GC of *other* versions can proceed freely while this
    snapshot stays readable.
    """

    def __init__(
        self, handle: BlobHandle, version: int, total_pages: int, page_size: int
    ) -> None:
        self.handle = handle
        self.version = version
        self._total_pages = total_pages
        self._page_size = page_size
        self._pinned = True
        self._pin_lock = make_lock("Snapshot._pin_lock")

    @property
    def blob_id(self) -> int:
        return self.handle.blob_id

    @property
    def pinned(self) -> bool:
        return self._pinned

    def read(self, offset_bytes: int, size_bytes: int) -> np.ndarray:
        return self.readv([(offset_bytes, size_bytes)])[0]

    def readv(self, segments: Sequence[Tuple[int, int]]) -> List[np.ndarray]:
        return self.handle.session._readv(
            self.handle.blob_id, self.version, segments,
            self._total_pages, self._page_size,
        )

    def release(self) -> None:
        """Drop the GC pin (idempotent). Reads remain possible afterwards but
        are no longer protected from a concurrent :meth:`Cluster.gc`."""
        with self._pin_lock:
            if not self._pinned:
                return
            self._pinned = False
        self.handle.session.cluster.unpin_version(self.handle.blob_id, self.version)

    def __enter__(self) -> "Snapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class VersionWatch:
    """Ordered publish subscription for one blob.

    :meth:`next` blocks until a version newer than the last delivered one is
    published and returns it; versions are delivered densely and strictly in
    order even when many writers publish concurrently (the consumer may lag —
    publications are never skipped, except abandoned holes, which were never
    readable). Iterating the watch yields versions forever."""

    def __init__(self, vm: VersionManager, blob_id: int, start_version: int) -> None:
        self._vm = vm
        self.blob_id = blob_id
        self.last_delivered = start_version

    def next(self, timeout: Optional[float] = None) -> Optional[int]:
        """The next published version after ``last_delivered``, or ``None``
        on timeout. Abandoned (never-readable) versions are skipped."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            target = self.last_delivered + 1
            remaining: Optional[float] = None
            if deadline is not None:
                remaining = max(0.0, deadline - time.monotonic())
            try:
                # fail_on_withdrawn=False: an erased version number may be
                # reissued to the next writer, and the watch must deliver it
                # then — only aborted holes (never readable) raise, and those
                # are stepped over without delivery
                if not self._vm.wait_published(
                    self.blob_id, target, remaining, fail_on_withdrawn=False
                ):
                    return None
            except VersionAbandoned:
                self.last_delivered = target
                continue
            self.last_delivered = target
            return target

    def drain(self) -> List[int]:
        """Every already-published undelivered version, without blocking."""
        out: List[int] = []
        while True:
            v = self.next(timeout=0)
            if v is None:
                return out
            out.append(v)

    def __iter__(self) -> Iterator[int]:
        while True:
            v = self.next()
            assert v is not None  # no timeout -> next() only returns versions
            yield v
