"""BlobStore: the paper's client-side access protocol (§III.B).

WRITE(id, buffer, offset, size) — an **overlapped pipeline**. The paper's
stages (data pages, version assignment, metadata weaving) are independent and
serialize only at the version manager, so the client never runs them with
barriers in between:

  1. ask the provider manager for placements (one per fresh page), then
     **launch** the per-provider ``put_pages`` RPCs — one aggregated put per
     provider — and do NOT wait for them;
  2. while the data puts are in flight, ask the version manager for version
     numbers + precomputed border links (the only serialized step — it does
     not depend on data-put completion);
  3. still while data flies, build every patch's metadata tree (weaving
     happens through the precomputed links — complete isolation from
     concurrent writers) and **launch** the per-shard ``put_nodes`` RPCs —
     one aggregated RPC per shard across the whole writev — the moment the
     shard batches are grouped;
  4. join ALL outstanding data and metadata futures — the single sync point;
  5. report success; the version manager publishes versions in order. The
     just-written pages are **written through** into the local page cache, so
     the writer's own re-reads skip the network entirely.

  If any put fails mid-pipeline, the write plane cleans up after itself:
  stored pages are deleted, placement load credits are released, stored
  metadata nodes are dropped, and the assigned versions are withdrawn via
  ``VersionManager.abandon`` so in-order publication can never wedge behind a
  writer that will never report success.

  ``BlobStore(sync_write=True)`` keeps the pre-pipeline behavior — a full
  barrier after every stage and a defensive copy per page — as the A/B
  baseline for the ``sync-write`` benchmark mode.

WRITE_ASYNC / FLUSH — cross-write overlap. :meth:`BlobStore.write_async`
queues a write into a bounded in-flight window (backpressure once
``max_inflight_writes`` are outstanding) and returns a future; a client can
stream many writes whose pipelines overlap each other while the version
manager still publishes strictly in assignment order. :meth:`BlobStore.flush`
joins the window and returns the assigned versions.

READ(id, v, buffer, offset, size):
  1. ask the version manager for the latest published version (fails if the
     requested version is unpublished or was abandoned) — one lock pass;
  2. traverse the segment tree of version v over the DHT (parallel per level);
  3. fetch the leaves' pages from the data providers in parallel.

Page transport is **zero-copy end to end**: ``writev`` freezes the source
buffer (read-only) and hands page-sized views to the providers — no per-page
copy on the hot path; providers store and return those arrays without
defensive copies (immutability makes sharing safe); ``readv`` assembles
multi-page segments by writing fetched pages directly into one preallocated
output buffer and serves a full-page single-page segment as a read-only view
of the stored/cached page itself.

On top of the paper's protocol this client adds two scaling layers that its
immutability guarantees make safe:

* a **versioned page cache** (:mod:`repro.core.page_cache`): a version's
  pages can never change once stored, so snapshot re-reads hit RAM with no
  invalidation protocol; concurrent cold misses on a page are collapsed into
  one provider fetch (single-flight); published writes write through;
* a **batched multi-segment data plane** — :meth:`BlobStore.readv` /
  :meth:`BlobStore.writev` take many segments, deduplicate shared pages, run
  ONE level-synchronous metadata traversal and ONE aggregated page RPC per
  provider across all segments (the paper's §V.A RPC aggregation, applied
  across an entire vectored request). ``read``/``write``/``write_unaligned``
  are thin wrappers over this plane.

All data-plane steps run on a thread pool to model the paper's concurrent
RPCs; the version manager interaction is the only serialization point.
"""

from __future__ import annotations

import dataclasses
import functools
import random
import threading
from collections import defaultdict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.dht import MetadataDHT, ProviderFailed, TrafficStats
from repro.core.page_cache import PageCache, ZERO_PAGE_CHARGE
from repro.core.provider import DataProvider, ProviderManager
from repro.core.replica_balancer import BalancerConfig, ReplicaBalancer
from repro.core.segment_tree import (
    NodeKey,
    PageRef,
    TreeNode,
    ZERO_VERSION,
    build_write_tree,
    traverse_batch,
)
from repro.core.version_manager import VersionManager

#: Default client page-cache budget (bytes); pass ``cache_bytes=0`` to disable.
DEFAULT_CACHE_BYTES = 64 << 20


@dataclasses.dataclass
class ReadResult:
    latest_published: int
    data: np.ndarray


@functools.lru_cache(maxsize=8)
def _zero_page(page_size: int) -> np.ndarray:
    page = np.zeros(page_size, dtype=np.uint8)
    page.flags.writeable = False
    return page


def _merge_ranges(pages: Sequence[int]) -> List[Tuple[int, int]]:
    """Collapse a sorted page-index list into (offset, size) runs."""
    ranges: List[Tuple[int, int]] = []
    for p in pages:
        if ranges and ranges[-1][0] + ranges[-1][1] == p:
            ranges[-1] = (ranges[-1][0], ranges[-1][1] + 1)
        else:
            ranges.append((p, 1))
    return ranges


class BlobStore:
    """Facade wiring clients to the five actors of the paper's architecture."""

    def __init__(
        self,
        n_data_providers: int = 4,
        n_metadata_providers: int = 4,
        page_replication: int = 1,
        metadata_replication: int = 1,
        max_workers: int = 8,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        replica_spread: bool = True,
        hot_replicas: bool = True,
        balancer_config: Optional[BalancerConfig] = None,
        page_service_seconds: float = 0.0,
        metadata_latency_seconds: float = 0.0,
        sync_write: bool = False,
        max_inflight_writes: int = 8,
    ) -> None:
        self.stats = TrafficStats()
        self.version_manager = VersionManager()
        self.provider_manager = ProviderManager(replication=page_replication, stats=self.stats)
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self.metadata = MetadataDHT(
            n_metadata_providers,
            replication=metadata_replication,
            stats=self.stats,
            executor=self._pool,
            rpc_latency_seconds=metadata_latency_seconds,
        )
        #: run writes with the pre-pipeline full barriers + per-page copies
        #: (the A/B baseline for the ``sync-write`` benchmark mode)
        self.sync_write = sync_write
        #: bounded in-flight window for :meth:`write_async`
        self.max_inflight_writes = max_inflight_writes
        self._write_window = threading.BoundedSemaphore(max_inflight_writes)
        self._writer_pool: Optional[ThreadPoolExecutor] = None
        self._writer_pool_lock = threading.Lock()
        self._async_lock = threading.Lock()
        self._async_writes: List[Future] = []
        self.page_cache: Optional[PageCache] = (
            PageCache(cache_bytes, stats=self.stats) if cache_bytes else None
        )
        #: pick the least-read-loaded replica per page instead of always the
        #: primary (the knob the skew-read benchmark flips)
        self.replica_spread = replica_spread
        self.page_service_seconds = page_service_seconds
        for i in range(n_data_providers):
            self.provider_manager.register(DataProvider(i, page_service_seconds))
        self.replica_balancer: Optional[ReplicaBalancer] = (
            ReplicaBalancer(
                self.provider_manager, self.metadata, self.stats, balancer_config
            )
            if hot_replicas
            else None
        )
        self._next_provider_id = n_data_providers
        self._membership_lock = threading.Lock()
        self._rng = random.Random(0xB10B)

    # -- elasticity ------------------------------------------------------------
    def add_data_provider(self) -> int:
        with self._membership_lock:
            pid = self._next_provider_id
            self._next_provider_id += 1
        self.provider_manager.register(DataProvider(pid, self.page_service_seconds))
        return pid

    # -- ALLOC -------------------------------------------------------------------
    def alloc(self, size_bytes: int, page_size: int) -> int:
        if page_size & (page_size - 1):
            raise ValueError("page_size must be a power of two")
        if size_bytes % page_size:
            raise ValueError("blob size must be a multiple of page_size")
        total_pages = size_bytes // page_size
        return self.version_manager.alloc(total_pages, page_size)

    # -- WRITE -------------------------------------------------------------------
    def write(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        """Patch ``blob_id`` with ``buffer`` at ``offset_bytes``; returns the
        assigned version (published once all earlier versions publish)."""
        return self.writev(blob_id, [(offset_bytes, buffer)])[0]

    def writev(
        self, blob_id: int, patches: Sequence[Tuple[int, np.ndarray]]
    ) -> List[int]:
        """Vectored WRITE: apply many ``(offset_bytes, buffer)`` page-aligned
        patches. Each patch gets its own version (identical semantics to a
        loop of :meth:`write`, in patch order), but the data plane batches
        AND pipelines: one placement call, ONE aggregated ``put_pages`` RPC
        per data provider across all patches launched up front, version
        assignment and metadata weaving while those puts are in flight, and a
        single join before success is reported. Returns the assigned
        versions.

        Zero-copy hand-off: the write plane freezes each source buffer that
        owns its memory (``writeable = False``) and providers keep page-sized
        views of it; a buffer passed to ``writev`` is surrendered to the
        store for good, whether the write succeeds or fails (another
        overlapping write may already share the frozen buffer, so failure
        cannot safely hand it back). Views of larger writable arrays cannot
        be frozen and are bulk-copied once per patch instead. Caveat the
        store cannot detect: a writable view the caller created BEFORE the
        call still aliases the frozen memory — mutating through it corrupts
        published data, exactly like scribbling over an O_DIRECT buffer with
        I/O in flight.
        """
        total_pages, page_size = self.version_manager.blob_info(blob_id)
        sync = self.sync_write
        # pass 1: validate and normalize every patch — no side effects yet,
        # so a bad later patch cannot leave earlier buffers frozen
        bufs: List[np.ndarray] = []
        spans: List[Tuple[int, int]] = []  # (page_offset, n_pages) per patch
        for offset_bytes, buffer in patches:
            src = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
            if offset_bytes % page_size or src.size % page_size:
                raise ValueError("WRITE must be page-aligned (paper §II)")
            n_pages = src.size // page_size
            if n_pages == 0:
                raise ValueError("empty write")
            bufs.append(src)
            spans.append((offset_bytes // page_size, n_pages))
        if not bufs:
            return []
        # pass 2 (pipelined only; the sync baseline copies every page anyway):
        # make each source immutable before any view of it is handed out.
        # Zero-copy is only safe when freezing the array that OWNS the memory
        # actually cuts off future writes — i.e. the caller passed the owning
        # array itself (or our normalization already copied). A view of some
        # larger writable array cannot be protected by freezing (writes
        # through the base would still mutate the stored pages), so that case
        # falls back to ONE bulk copy per patch — never a per-page copy.
        if not sync:
            for i, (src, (_, buffer)) in enumerate(zip(bufs, patches)):
                root = src
                while isinstance(root.base, np.ndarray):
                    root = root.base
                if root.flags.writeable:
                    caller_root = buffer
                    while isinstance(caller_root, np.ndarray) and isinstance(
                        caller_root.base, np.ndarray
                    ):
                        caller_root = caller_root.base
                    owns = root is not caller_root or (
                        isinstance(buffer, np.ndarray) and buffer.base is None
                    )
                    if owns:
                        root.flags.writeable = False
                    else:
                        src = bufs[i] = src.copy()
                        src.flags.writeable = False
                ro = src.view()
                ro.flags.writeable = False
                bufs[i] = ro

        # (1) placements for every fresh page of every patch, in one call
        placements = self.provider_manager.allocate(sum(n for _, n in spans))

        by_provider: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        per_patch: List[List[Tuple[PageRef, Tuple[PageRef, ...]]]] = []
        #: per patch, the page arrays actually handed to the store (views in
        #: the pipelined path, copies in the sync baseline) — the write-through
        #: cache must reference these, never a possibly-writable source
        stored_pages: List[List[np.ndarray]] = []
        versions: List[int] = []
        node_keys: List[NodeKey] = []
        data_futures: List[Future] = []
        meta_futures: List[Future] = []
        try:
            cursor = 0
            for src, (_, n_pages) in zip(bufs, spans):
                mine = placements[cursor : cursor + n_pages]
                cursor += n_pages
                per_patch.append(mine)
                pages: List[np.ndarray] = []
                for i, (primary, replicas) in enumerate(mine):
                    page = src[i * page_size : (i + 1) * page_size]
                    if sync:
                        page = page.copy()  # pre-pipeline baseline: defensive copy
                    pages.append(page)
                    for pid, key in (primary,) + replicas:
                        by_provider.setdefault(pid, []).append((key, page))
                stored_pages.append(pages)

            # (2) LAUNCH the aggregated per-provider puts; the pipeline only
            #     joins them at the end (sync baseline: full barrier here)
            data_futures = [
                self._pool.submit(self._put_batch, pid, items)
                for pid, items in by_provider.items()
            ]
            if sync:
                for f in data_futures:
                    f.result()

            # (3) version numbers + border links for ALL patches under ONE
            #     manager lock acquisition (the only serialized step) — this
            #     does not depend on data-put completion, so it runs while
            #     the pages are still in flight
            assigned = self.version_manager.assign_versions(blob_id, spans)
            versions = [v for v, _ in assigned]

            # (4) weave every patch's tree while the data puts are still in
            #     flight, then LAUNCH one aggregated node put per shard
            #     (paper §V.A aggregation across the whole writev); the sync
            #     baseline runs the same aggregated put behind a barrier
            all_nodes: List[TreeNode] = []
            for (page_offset, n_pages), mine, (version, links) in zip(
                spans, per_patch, assigned
            ):
                all_nodes.extend(
                    build_write_tree(
                        blob_id, version, total_pages, page_offset, n_pages, mine, links
                    )
                )
            node_keys.extend(node.key for node in all_nodes)
            if sync:
                self.metadata.put_nodes(all_nodes)
            else:
                meta_futures.extend(self.metadata.put_nodes_async(all_nodes))

            # join: every page and node must be durable before success
            for f in data_futures + meta_futures:
                err = f.exception()
                if err is not None:
                    raise err

            # (5) report success (one lock for the batch) → in-order publish
            self.version_manager.report_successes(blob_id, versions)
        except BaseException:
            # NOTE: frozen sources stay frozen — a concurrent write may
            # already hold zero-copy views of the same root, so restoring
            # writability here would let the caller mutate ITS published
            # pages through the shared memory
            self._abort_writev(
                blob_id, versions, placements, by_provider, node_keys,
                data_futures, meta_futures,
            )
            raise

        # write-through: the just-stored pages are already immutable, so the
        # writer's re-reads of these versions come straight from RAM
        if self.page_cache is not None:
            items: List[Tuple[Tuple[int, int, int], np.ndarray]] = []
            for pages, (page_offset, _), version in zip(
                stored_pages, spans, versions
            ):
                for i, page in enumerate(pages):
                    items.append(((blob_id, version, page_offset + i), page))
            self.page_cache.put_many(items)
        return versions

    def _put_batch(self, pid: int, items: List[Tuple[int, np.ndarray]]) -> None:
        self.provider_manager.get_provider(pid).put_pages(items)
        self.stats.record_data(pid, len(items), sum(p.nbytes for _, p in items))

    def _abort_writev(
        self,
        blob_id: int,
        versions: List[int],
        placements: List[Tuple[PageRef, Tuple[PageRef, ...]]],
        by_provider: Dict[int, List[Tuple[int, np.ndarray]]],
        node_keys: List[NodeKey],
        data_futures: List[Future],
        meta_futures: List[Future],
    ) -> None:
        """Failure cleanup for a mid-flight ``writev``: without this, the
        placement load heap keeps phantom load, stored pages and nodes of the
        doomed versions leak forever, and in-order publication wedges behind
        versions that will never report success.

        The doomed versions are withdrawn first; what happens to their
        stored wreckage depends on how :meth:`VersionManager.abandon`
        resolved them. Fully *erased* versions (no concurrent writer assigned
        after them) are scrubbed: pages deleted, nodes deleted, placement
        credits released. Versions that became publication *holes* are left
        in place instead — a later writer may already have woven border links
        into their trees, so deleting whatever did land would turn that
        writer's published version unreadable; the wreckage stays until
        :meth:`BlobStore.gc` collects it (which also returns the load
        credit), the same stance taken for orphans on a down provider."""
        for f in data_futures + meta_futures:
            f.exception()  # quiesce: no put may still be in flight
        if versions:
            holes = self.version_manager.abandon(blob_id, versions)
            if holes:
                return  # leak to GC: later versions may reference the nodes
        for pid, items in by_provider.items():
            try:  # best-effort: a down provider keeps its orphans until GC
                self.provider_manager.get_provider(pid).delete_pages(
                    [key for key, _ in items]
                )
            except (ProviderFailed, KeyError):
                pass
        try:
            self.metadata.delete_nodes(node_keys)
        except ProviderFailed:
            pass
        self.provider_manager.release(
            [ref for primary, replicas in placements for ref in (primary,) + replicas]
        )

    # -- asynchronous write streaming ------------------------------------------
    def write_async(
        self, blob_id: int, buffer: np.ndarray, offset_bytes: int
    ) -> "Future[int]":
        """Queue a :meth:`write` into the bounded in-flight window and return
        a future of its assigned version. Blocks (backpressure) once
        ``max_inflight_writes`` writes are outstanding. Successive writes'
        pipelines overlap — a later write's pages may land before an earlier
        write's metadata — while the version manager still publishes strictly
        in assignment order. Join the window with :meth:`flush` (or await the
        returned future)."""
        self._write_window.acquire()
        try:
            future = self._writers().submit(
                self._windowed_write, blob_id, buffer, offset_bytes
            )
        except BaseException:
            self._write_window.release()
            raise
        with self._async_lock:
            # prune successfully-completed futures so a long-running streamer
            # that joins its own returned futures (never calls flush) does
            # not accumulate them forever; FAILED futures are kept until
            # flush()/close() so their errors cannot vanish unobserved
            self._async_writes = [
                f for f in self._async_writes
                if not f.done() or f.exception() is not None
            ]
            self._async_writes.append(future)
        return future

    def _writers(self) -> ThreadPoolExecutor:
        with self._writer_pool_lock:
            if self._writer_pool is None:
                self._writer_pool = ThreadPoolExecutor(
                    max_workers=self.max_inflight_writes
                )
            return self._writer_pool

    def _windowed_write(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        try:
            return self.writev(blob_id, [(offset_bytes, buffer)])[0]
        finally:
            self._write_window.release()

    def flush(self) -> List[int]:
        """Join every outstanding :meth:`write_async` — STORE-GLOBAL: it
        drains the whole window, including writes queued by other threads
        sharing this store (a multi-writer client should instead join the
        futures ``write_async`` returned to it). Returns the versions of the
        writes still tracked by the window (writes that completed and were
        already pruned are not re-reported) and re-raises the first
        failure."""
        with self._async_lock:
            futures, self._async_writes = self._async_writes, []
        versions: List[int] = []
        first_err: Optional[BaseException] = None
        for f in futures:
            try:
                versions.append(f.result())
            except BaseException as err:  # keep joining; surface the first
                if first_err is None:
                    first_err = err
        if first_err is not None:
            raise first_err
        return versions

    # -- READ --------------------------------------------------------------------
    def read(
        self,
        blob_id: int,
        version: Optional[int],
        offset_bytes: int,
        size_bytes: int,
    ) -> ReadResult:
        """Read ``[offset_bytes, offset_bytes+size_bytes)`` of ``version``
        (``None`` = latest published). Fails if ``version`` is unpublished,
        abandoned, or the range is fully out of bounds; a range overlapping
        the blob's end is clamped (short read). A read of exactly one whole
        page returns a read-only view of the stored/cached page (zero-copy);
        copy before mutating."""
        total_pages, page_size, version, latest = (
            self.version_manager.resolve_read_version(blob_id, version)
        )
        data = self._readv(
            blob_id, version, [(offset_bytes, size_bytes)], total_pages, page_size
        )[0]
        return ReadResult(latest, data)

    def readv(
        self,
        blob_id: int,
        version: Optional[int],
        segments: Sequence[Tuple[int, int]],
    ) -> List[np.ndarray]:
        """Vectored READ: fetch many ``(offset_bytes, size_bytes)`` segments
        of one version in a single batched pass. Pages shared between
        segments are deduplicated; cache hits skip the network entirely; the
        remaining pages cost one level-synchronous metadata traversal (one
        aggregated RPC per shard per level) plus ONE aggregated ``get_pages``
        RPC per data provider. Returns one ``np.uint8`` array per segment
        (full-single-page segments are read-only zero-copy views).
        """
        total_pages, page_size, version, _ = (
            self.version_manager.resolve_read_version(blob_id, version)
        )
        return self._readv(blob_id, version, segments, total_pages, page_size)

    def _readv(
        self,
        blob_id: int,
        version: int,
        segments: Sequence[Tuple[int, int]],
        total_pages: int,
        page_size: int,
    ) -> List[np.ndarray]:
        """``readv`` body with the version-manager state already resolved —
        the serialized actor is consulted exactly once per public call."""
        # clamp segments; collect the deduplicated union of needed pages
        total_bytes = total_pages * page_size
        clamped: List[Tuple[int, int]] = []
        needed: Set[int] = set()
        for offset, size in segments:
            if offset < 0 or size < 0:
                raise ValueError(f"negative read offset/size ({offset}, {size})")
            if size == 0:
                clamped.append((offset, 0))
                continue
            if offset >= total_bytes:
                raise ValueError(
                    f"read at offset {offset} out of range (blob is {total_bytes} bytes)"
                )
            size = min(size, total_bytes - offset)  # clamp to blob end
            clamped.append((offset, size))
            first_page = offset // page_size
            last_page = min(-(-(offset + size) // page_size), total_pages)
            needed.update(range(first_page, last_page))

        # cache phase: hits are served from RAM; exactly one concurrent
        # reader becomes the fetch leader for each missing page
        pages: Dict[int, Optional[np.ndarray]] = {}
        cache = self.page_cache
        owned: List[int] = []
        waits: Dict[Tuple[int, int, int], object] = {}
        if cache is not None and needed:
            plan = cache.plan([(blob_id, version, p) for p in sorted(needed)])
            pages.update({key[2]: page for key, page in plan.hits.items()})
            owned = sorted(key[2] for key in plan.owned)
            waits = plan.waits
        else:
            owned = sorted(needed)

        if owned:
            fulfilled: Set[int] = set()
            try:
                # (2) ONE metadata traversal pass over all missed ranges
                leaves = traverse_batch(
                    self.metadata.get_nodes, blob_id, version, total_pages,
                    _merge_ranges(owned),
                )
                # (3) ONE aggregated page fetch per provider
                fetched = self._fetch_pages(leaves, page_size)
                for p, page in fetched.items():
                    pages[p] = page
                    if cache is not None:
                        # zero pages share one buffer — charge them the LRU
                        # slot, not a full page, so repeat sparse reads skip
                        # the metadata walk without evicting real pages
                        cache.fulfill(
                            (blob_id, version, p),
                            page if page is not None else _zero_page(page_size),
                            charge=None if page is not None else ZERO_PAGE_CHARGE,
                        )
                        fulfilled.add(p)
            except BaseException as err:
                if cache is not None:
                    for p in owned:
                        if p not in fulfilled:
                            cache.abort((blob_id, version, p), err)
                raise

        # follower phase: collect pages fetched by concurrent leaders
        for key, flight in waits.items():
            pages[key[2]] = cache.wait(key, flight)  # type: ignore[union-attr, arg-type]

        # assemble per-segment outputs from the shared page map: a segment
        # covering exactly one whole page is served as a zero-copy read-only
        # view of that page; anything else is written page-by-page directly
        # into one preallocated output buffer
        outs: List[np.ndarray] = []
        for offset, size in clamped:
            if size == page_size and offset % page_size == 0:
                page = pages.get(offset // page_size)
                outs.append(page if page is not None else _zero_page(page_size))
                continue
            out = np.zeros(size, dtype=np.uint8)
            for p in range(offset // page_size, -(-(offset + size) // page_size)):
                page = pages.get(p)
                if page is None:
                    continue  # implicit zero page
                page_lo = p * page_size
                a = max(offset, page_lo)
                b = min(offset + size, page_lo + page_size)
                out[a - offset : b - offset] = page[a - page_lo : b - page_lo]
            outs.append(out)
        return outs

    def _choose_ref(
        self, leaf: TreeNode, read_load: Dict[int, int], page_size: int
    ) -> PageRef:
        """Pick which replica serves this page via power-of-two random
        choices: sample two replicas, take the one with less read traffic so
        far, charging ``read_load`` tentatively so one batch also spreads.
        The random sampling is what prevents the herd effect — a
        deterministic global minimum sends every concurrent client to the
        same momentarily-idle provider, re-serializing the hot page there."""
        refs = leaf.all_page_refs()
        a, b = self._rng.sample(range(len(refs)), 2)
        pid, key = min(
            refs[a], refs[b], key=lambda r: read_load.get(r[0], 0)
        )
        read_load[pid] = read_load.get(pid, 0) + page_size
        return pid, key

    def _fetch_pages(
        self, leaves: Dict[int, Optional[TreeNode]], page_size: int
    ) -> Dict[int, Optional[np.ndarray]]:
        """Fetch all leaf pages: one aggregated RPC per serving provider (in
        parallel), per-page replica fallback if a provider batch fails. The
        serving provider per page is replica-spread (least read load) rather
        than always the primary, and every provider fetch feeds the replica
        balancer's heat counters."""
        result: Dict[int, Optional[np.ndarray]] = {}
        by_provider: Dict[int, List[Tuple[int, int, TreeNode]]] = defaultdict(list)
        # stats snapshot is deferred until a leaf actually has a choice to
        # make — single-replica reads must not pay a global-lock round-trip
        read_load: Optional[Dict[int, int]] = None
        for page_index, leaf in leaves.items():
            if leaf is None:
                result[page_index] = None  # implicit zero page
                continue
            if self.replica_spread and len(leaf.all_page_refs()) > 1:
                if read_load is None:
                    read_load = self.stats.read_bytes_snapshot()
                pid, key = self._choose_ref(leaf, read_load, page_size)
            else:
                pid, key = leaf.page  # type: ignore[misc]
            by_provider[pid].append((page_index, key, leaf))

        def _get_batch(
            pid: int, items: List[Tuple[int, int, TreeNode]]
        ) -> Optional[Dict[int, np.ndarray]]:
            try:
                provider = self.provider_manager.get_provider(pid)
                fetched = provider.get_pages([key for _, key, _ in items])
            except (ProviderFailed, KeyError):
                return None  # provider down/deregistered: caller falls back
            self.stats.record_data(
                pid, len(items), sum(pg.nbytes for pg in fetched), read=True
            )
            return {p: pg for (p, _, _), pg in zip(items, fetched)}

        batches = list(by_provider.items())
        futures = [self._pool.submit(_get_batch, pid, items) for pid, items in batches]
        fallback: List[Tuple[int, TreeNode, int]] = []
        for (pid, items), f in zip(batches, futures):
            got = f.result()
            if got is None:
                fallback.extend((p, leaf, pid) for p, _, leaf in items)
            else:
                result.update(got)
        if fallback:
            # replica fallback in parallel, skipping the observed-dead choice
            fb = [
                self._pool.submit(self._fetch_single, p, leaf, skip)
                for p, leaf, skip in fallback
            ]
            for (p, _, _), f in zip(fallback, fb):
                result[p] = f.result()
        if self.replica_balancer is not None:
            self.replica_balancer.note_fetches(
                items[2] for batch in by_provider.values() for items in batch
            )
        return result

    def _fetch_single(
        self, page_index: int, leaf: TreeNode, skip_pid: Optional[int] = None
    ) -> np.ndarray:
        refs = [r for r in leaf.all_page_refs() if r[0] != skip_pid]
        last_err: Optional[Exception] = None
        for pid, key in refs or leaf.all_page_refs():
            try:
                page = self.provider_manager.get_provider(pid).get_page(key)
                self.stats.record_data(pid, 1, page.nbytes, read=True)
                return page
            except (ProviderFailed, KeyError) as err:
                last_err = err
        raise last_err if last_err else KeyError(f"page {page_index} unavailable")

    def write_unaligned(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        """WRITE at arbitrary byte offset/size via client-side read-modify-write
        of the boundary pages (the paper's API allows arbitrary segments; pages
        are the storage granularity, so partial boundary pages are merged from
        the latest published version before patching). Both boundary pages are
        fetched in one :meth:`readv` call, so hot boundary pages come straight
        from the page cache.

        Note the concurrency caveat the paper implies: the boundary merge reads
        the LATEST version, so two concurrent unaligned writers sharing a
        boundary page serialize at page granularity like any COW system.
        """
        _, page_size = self.version_manager.blob_info(blob_id)
        buffer = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
        lo = offset_bytes // page_size * page_size
        hi = -(-(offset_bytes + buffer.size) // page_size) * page_size
        if lo == offset_bytes and hi == offset_bytes + buffer.size:
            return self.write(blob_id, buffer, offset_bytes)
        merged = np.zeros(hi - lo, np.uint8)
        boundary_segs: List[Tuple[int, int]] = []
        if lo < offset_bytes:  # left boundary page
            boundary_segs.append((lo, page_size))
        if hi > offset_bytes + buffer.size:  # right boundary page
            boundary_segs.append((hi - page_size, page_size))
        boundary = self.readv(blob_id, None, boundary_segs)
        for (seg_off, _), data in zip(boundary_segs, boundary):
            merged[seg_off - lo : seg_off - lo + page_size] = data
        merged[offset_bytes - lo : offset_bytes - lo + buffer.size] = buffer
        return self.write(blob_id, merged, lo)

    # -- GC (paper future work) -----------------------------------------------------
    def gc(self, blob_id: int, keep_versions: Sequence[int]) -> Tuple[int, int]:
        """Drop all tree nodes / pages unreachable from ``keep_versions``.

        Must be invoked only when no concurrent accesses target the dropped
        versions (the paper's "ordered by the client" semantics). Cached pages
        of dropped versions are purged as well. Promotion passes are paused
        for the duration — an in-flight promotion could otherwise re-create a
        just-deleted leaf node or copy a page GC is about to drop. Returns
        (nodes_freed, pages_freed).
        """
        if self.replica_balancer is not None:
            with self.replica_balancer.paused():
                return self._gc_locked(blob_id, keep_versions)
        return self._gc_locked(blob_id, keep_versions)

    def _gc_locked(self, blob_id: int, keep_versions: Sequence[int]) -> Tuple[int, int]:
        total_pages, _ = self.version_manager.blob_info(blob_id)
        latest = self.version_manager.latest_published(blob_id)
        keep = sorted(set(v for v in keep_versions if v != ZERO_VERSION))
        reachable_nodes: Set[NodeKey] = set()
        reachable_pages: Set[PageRef] = set()

        def mark(version: int, offset: int, size: int) -> None:
            if version == ZERO_VERSION:
                return
            key = NodeKey(blob_id, version, offset, size)
            if key in reachable_nodes:
                return
            node = self.metadata.get_node(key)
            reachable_nodes.add(key)
            if node.is_leaf:
                reachable_pages.update(node.all_page_refs())
                return
            half = size // 2
            mark(node.left_version, offset, half)
            mark(node.right_version, offset + half, half)

        for v in keep:
            mark(v, 0, total_pages)

        # Enumerate every stored node of this blob and drop unreachable ones.
        doomed_nodes: List[NodeKey] = []
        doomed_pages: Set[PageRef] = set()
        for key, node in self.metadata.iter_nodes(blob_id):
            if key.version > latest:
                continue  # never GC in-flight (unpublished) versions
            if key not in reachable_nodes:
                doomed_nodes.append(key)
                if node.is_leaf:
                    doomed_pages.update(ref for ref in node.all_page_refs())
        doomed_pages -= reachable_pages
        self.metadata.delete_nodes(doomed_nodes)
        if self.replica_balancer is not None:
            # demote-on-GC: the promoted copies die with the doomed leaves
            # (they are in the rewritten nodes' all_page_refs above); drop the
            # balancer's heat/promotion records so they can't be re-targeted
            self.replica_balancer.forget(doomed_nodes)
        by_provider: Dict[int, List[int]] = {}
        for pid, key in doomed_pages:
            by_provider.setdefault(pid, []).append(key)
        for pid, keys in by_provider.items():
            self.provider_manager.get_provider(pid).delete_pages(keys)
        self.provider_manager.release(sorted(doomed_pages))
        if self.page_cache is not None:
            self.page_cache.drop_versions(blob_id, set(keep) | {ZERO_VERSION})
        return len(doomed_nodes), len(doomed_pages)

    # -- introspection ------------------------------------------------------------
    def storage_bytes(self) -> int:
        return sum(p.used_bytes() for p in self.provider_manager.providers())

    def close(self) -> None:
        # quiesce the async write window first; errors are the caller's to
        # observe via flush()/the returned futures, not close()
        with self._async_lock:
            futures, self._async_writes = self._async_writes, []
        for f in futures:
            f.exception()
        with self._writer_pool_lock:
            if self._writer_pool is not None:
                self._writer_pool.shutdown(wait=True)
                self._writer_pool = None
        self.metadata.close()
        self._pool.shutdown(wait=True)
