"""BlobStore: the paper's client-side access protocol (§III.B).

WRITE(id, buffer, offset, size):
  1. ask the provider manager for placements (one per fresh page);
  2. store pages on the data providers **in parallel**;
  3. ask the version manager for a version number + precomputed border links
     (the only serialized step);
  4. build the new metadata tree and store its nodes on the metadata DHT in
     parallel (weaving happens through the precomputed links — complete
     isolation from concurrent writers);
  5. report success; the version manager publishes versions in order.

READ(id, v, buffer, offset, size):
  1. ask the version manager for the latest published version (fails if the
     requested version is unpublished);
  2. traverse the segment tree of version v over the DHT (parallel per level);
  3. fetch the leaves' pages from the data providers in parallel.

All data-plane steps run on a thread pool to model the paper's concurrent
RPCs; the version manager interaction is the only serialization point.
"""

from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.dht import MetadataDHT, ProviderFailed, TrafficStats
from repro.core.provider import DataProvider, ProviderManager
from repro.core.segment_tree import (
    NodeKey,
    PageRef,
    TreeNode,
    ZERO_VERSION,
    build_write_tree,
    traverse,
)
from repro.core.version_manager import VersionManager


@dataclasses.dataclass
class ReadResult:
    latest_published: int
    data: np.ndarray


class BlobStore:
    """Facade wiring clients to the five actors of the paper's architecture."""

    def __init__(
        self,
        n_data_providers: int = 4,
        n_metadata_providers: int = 4,
        page_replication: int = 1,
        metadata_replication: int = 1,
        max_workers: int = 8,
    ) -> None:
        self.stats = TrafficStats()
        self.version_manager = VersionManager()
        self.provider_manager = ProviderManager(replication=page_replication, stats=self.stats)
        self.metadata = MetadataDHT(
            n_metadata_providers, replication=metadata_replication, stats=self.stats
        )
        for i in range(n_data_providers):
            self.provider_manager.register(DataProvider(i))
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._next_provider_id = n_data_providers
        self._membership_lock = threading.Lock()

    # -- elasticity ------------------------------------------------------------
    def add_data_provider(self) -> int:
        with self._membership_lock:
            pid = self._next_provider_id
            self._next_provider_id += 1
        self.provider_manager.register(DataProvider(pid))
        return pid

    # -- ALLOC -------------------------------------------------------------------
    def alloc(self, size_bytes: int, page_size: int) -> int:
        if page_size & (page_size - 1):
            raise ValueError("page_size must be a power of two")
        if size_bytes % page_size:
            raise ValueError("blob size must be a multiple of page_size")
        total_pages = size_bytes // page_size
        return self.version_manager.alloc(total_pages, page_size)

    # -- WRITE -------------------------------------------------------------------
    def write(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        """Patch ``blob_id`` with ``buffer`` at ``offset_bytes``; returns the
        assigned version (published once all earlier versions publish)."""
        total_pages, page_size = self.version_manager.blob_info(blob_id)
        buffer = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
        if offset_bytes % page_size or buffer.size % page_size:
            raise ValueError("WRITE must be page-aligned (paper §II)")
        page_offset = offset_bytes // page_size
        n_pages = buffer.size // page_size
        if n_pages == 0:
            raise ValueError("empty write")

        # (1) placements
        placements = self.provider_manager.allocate(n_pages)

        # (2) store pages in parallel, one aggregated put per provider
        by_provider: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        for i, (primary, replicas) in enumerate(placements):
            page = buffer[i * page_size : (i + 1) * page_size].copy()
            for pid, key in (primary,) + replicas:
                by_provider.setdefault(pid, []).append((key, page))

        def _put(pid: int, items: List[Tuple[int, np.ndarray]]) -> None:
            self.provider_manager.get_provider(pid).put_pages(items)
            self.stats.record(pid, len(items), sum(p.nbytes for _, p in items))

        futures = [self._pool.submit(_put, pid, items) for pid, items in by_provider.items()]
        for f in futures:
            f.result()

        # (3) version number + border links (the only serialized step)
        version, links = self.version_manager.assign_version(blob_id, page_offset, n_pages)

        # (4) build + store metadata nodes (parallelized inside put_nodes by
        #     aggregation per shard)
        nodes = build_write_tree(
            blob_id, version, total_pages, page_offset, n_pages, placements, links
        )
        self.metadata.put_nodes(nodes)

        # (5) report success → in-order publish
        self.version_manager.report_success(blob_id, version)
        return version

    # -- READ --------------------------------------------------------------------
    def read(
        self,
        blob_id: int,
        version: Optional[int],
        offset_bytes: int,
        size_bytes: int,
    ) -> ReadResult:
        """Read ``[offset_bytes, offset_bytes+size_bytes)`` of ``version``
        (``None`` = latest published). Fails if ``version`` is unpublished."""
        total_pages, page_size = self.version_manager.blob_info(blob_id)
        latest = self.version_manager.latest_published(blob_id)
        if version is None:
            version = latest
        elif version > latest:
            raise ValueError(f"version {version} not yet published (latest={latest})")

        first_page = offset_bytes // page_size
        last_page = (offset_bytes + size_bytes + page_size - 1) // page_size
        n_pages = max(last_page - first_page, 0)
        out = np.zeros(n_pages * page_size, dtype=np.uint8)
        if size_bytes == 0:
            return ReadResult(latest, out[:0])

        # (2) metadata traversal over the DHT
        leaves = list(
            traverse(self.metadata.get_node, blob_id, version, total_pages, first_page, n_pages)
        )

        # (3) parallel page fetch, aggregated per provider, replica fallback
        def _fetch(page_index: int, leaf: Optional[TreeNode]) -> None:
            if leaf is None:
                return  # implicit zero page
            base = (page_index - first_page) * page_size
            last_err: Optional[Exception] = None
            for pid, key in leaf.all_page_refs():
                try:
                    page = self.provider_manager.get_provider(pid).get_page(key)
                    self.stats.record(pid, 1, page.nbytes)
                    out[base : base + page_size] = page
                    return
                except (ProviderFailed, KeyError) as err:
                    last_err = err
            raise last_err if last_err else KeyError(f"page {page_index} unavailable")

        futures = [self._pool.submit(_fetch, idx, leaf) for idx, leaf in leaves]
        for f in futures:
            f.result()

        lo = offset_bytes - first_page * page_size
        return ReadResult(latest, out[lo : lo + size_bytes])

    def write_unaligned(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        """WRITE at arbitrary byte offset/size via client-side read-modify-write
        of the boundary pages (the paper's API allows arbitrary segments; pages
        are the storage granularity, so partial boundary pages are merged from
        the latest published version before patching).

        Note the concurrency caveat the paper implies: the boundary merge reads
        the LATEST version, so two concurrent unaligned writers sharing a
        boundary page serialize at page granularity like any COW system.
        """
        _, page_size = self.version_manager.blob_info(blob_id)
        buffer = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
        lo = offset_bytes // page_size * page_size
        hi = -(-(offset_bytes + buffer.size) // page_size) * page_size
        if lo == offset_bytes and hi == offset_bytes + buffer.size:
            return self.write(blob_id, buffer, offset_bytes)
        merged = np.zeros(hi - lo, np.uint8)
        if lo < offset_bytes:  # left boundary page
            merged[:page_size] = self.read(blob_id, None, lo, page_size).data
        if hi > offset_bytes + buffer.size:  # right boundary page
            merged[-page_size:] = self.read(blob_id, None, hi - page_size, page_size).data
        merged[offset_bytes - lo : offset_bytes - lo + buffer.size] = buffer
        return self.write(blob_id, merged, lo)

    # -- GC (paper future work) -----------------------------------------------------
    def gc(self, blob_id: int, keep_versions: Sequence[int]) -> Tuple[int, int]:
        """Drop all tree nodes / pages unreachable from ``keep_versions``.

        Must be invoked only when no concurrent accesses target the dropped
        versions (the paper's "ordered by the client" semantics). Returns
        (nodes_freed, pages_freed).
        """
        total_pages, _ = self.version_manager.blob_info(blob_id)
        latest = self.version_manager.latest_published(blob_id)
        keep = sorted(set(v for v in keep_versions if v != ZERO_VERSION))
        reachable_nodes: Set[NodeKey] = set()
        reachable_pages: Set[PageRef] = set()

        def mark(version: int, offset: int, size: int) -> None:
            if version == ZERO_VERSION:
                return
            key = NodeKey(blob_id, version, offset, size)
            if key in reachable_nodes:
                return
            node = self.metadata.get_node(key)
            reachable_nodes.add(key)
            if node.is_leaf:
                reachable_pages.update(node.all_page_refs())
                return
            half = size // 2
            mark(node.left_version, offset, half)
            mark(node.right_version, offset + half, half)

        for v in keep:
            mark(v, 0, total_pages)

        # Enumerate every stored node of this blob and drop unreachable ones.
        doomed_nodes: List[NodeKey] = []
        doomed_pages: Set[PageRef] = set()
        for shard in self.metadata.shards:
            for key, node in list(shard._nodes.items()):
                if key.blob_id != blob_id or key.version > latest:
                    continue  # never GC in-flight (unpublished) versions
                if key not in reachable_nodes:
                    doomed_nodes.append(key)
                    if node.is_leaf:
                        doomed_pages.update(ref for ref in node.all_page_refs())
        doomed_pages -= reachable_pages
        self.metadata.delete_nodes(doomed_nodes)
        by_provider: Dict[int, List[int]] = {}
        for pid, key in doomed_pages:
            by_provider.setdefault(pid, []).append(key)
        for pid, keys in by_provider.items():
            self.provider_manager.get_provider(pid).delete_pages(keys)
        self.provider_manager.release(sorted(doomed_pages))
        return len(doomed_nodes), len(doomed_pages)

    # -- introspection ------------------------------------------------------------
    def storage_bytes(self) -> int:
        return sum(p.used_bytes() for p in self.provider_manager.providers())

    def close(self) -> None:
        self._pool.shutdown(wait=True)
