"""Deprecated facade: ``BlobStore`` = one :class:`Cluster` + one
:class:`Session`.

The god-object API this module used to implement was split into the layered
:mod:`repro.core.cluster` API — :class:`~repro.core.cluster.Cluster` (shared
plane), :class:`~repro.core.cluster.Session` (per-client state) and
:class:`~repro.core.cluster.BlobHandle` (fine-grain ops, snapshots, version
watches). ``BlobStore`` remains as a thin compatibility wrapper so external
callers keep working one release longer; it constructs a private cluster
with the shared cache tier DISABLED (the pre-split topology: one client, one
cache) and forwards every old entry point to the single session. It emits a
:class:`DeprecationWarning` on construction and is used nowhere else inside
this repository — CI runs a ``-W error::DeprecationWarning`` leg to keep it
that way.

Migration map (old → new)::

    BlobStore(...)                    Cluster(...); session = cluster.session()
    store.alloc(size, page)           cluster.alloc(size, page)  /  session.create(size, page)
    store.read(b, v, off, sz)         session.open(b).read(off, sz, version=v)
    store.readv(b, v, segs)           session.open(b).readv(segs, version=v)
    store.write(b, buf, off)          handle.write(buf, off)
    store.writev(b, patches)          handle.writev(patches)
    store.write_async(b, buf, off)    handle.write_async(buf, off)
    store.flush()                     session.flush()
    store.write_unaligned(...)        handle.write_unaligned(buf, off)
    store.gc(b, keep)                 cluster.gc(b, keep)
    store.page_cache                  session.cache  (+ cluster.shared_cache)
    store.stats                       cluster.stats  (+ session.stats per client)
    —                                 handle.snapshot() / handle.at(v)   (pinned lock-free reads)
    —                                 handle.watch() / handle.wait_for_version(v)
"""

from __future__ import annotations

import warnings
from concurrent.futures import Future
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.cluster import (
    BlobHandle,
    Cluster,
    DEFAULT_CACHE_BYTES,
    ReadResult,
    Session,
)
from repro.core.replica_balancer import BalancerConfig

__all__ = ["BlobStore", "DEFAULT_CACHE_BYTES", "ReadResult"]


class BlobStore:
    """Deprecated single-client facade over ``Cluster`` + ``Session``."""

    def __init__(
        self,
        n_data_providers: int = 4,
        n_metadata_providers: int = 4,
        page_replication: int = 1,
        metadata_replication: int = 1,
        max_workers: int = 8,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        replica_spread: bool = True,
        hot_replicas: bool = True,
        balancer_config: Optional[BalancerConfig] = None,
        page_service_seconds: float = 0.0,
        metadata_latency_seconds: float = 0.0,
        sync_write: bool = False,
        max_inflight_writes: int = 8,
    ) -> None:
        warnings.warn(
            "BlobStore is deprecated: use Cluster/Session/BlobHandle "
            "(repro.core.cluster) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self.cluster = Cluster(
            n_data_providers=n_data_providers,
            n_metadata_providers=n_metadata_providers,
            page_replication=page_replication,
            metadata_replication=metadata_replication,
            max_workers=max_workers,
            shared_cache_bytes=0,  # pre-split topology: one client, one cache
            hot_replicas=hot_replicas,
            balancer_config=balancer_config,
            page_service_seconds=page_service_seconds,
            metadata_latency_seconds=metadata_latency_seconds,
        )
        self.session: Session = self.cluster.session(
            cache_bytes=cache_bytes,
            replica_spread=replica_spread,
            sync_write=sync_write,
            max_inflight_writes=max_inflight_writes,
        )
        #: blob_id -> handle; blob geometry is immutable after alloc, so the
        #: facade must not pay a fresh blob_info lock round-trip per call
        self._handles: dict = {}
        self._handles_lock = make_lock("BlobStore._handles_lock")

    # -- shared-plane attributes the old object exposed directly ---------------
    @property
    def stats(self):
        return self.cluster.stats

    @property
    def version_manager(self):
        return self.cluster.version_manager

    @property
    def provider_manager(self):
        return self.cluster.provider_manager

    @property
    def metadata(self):
        return self.cluster.metadata

    @property
    def replica_balancer(self):
        return self.cluster.replica_balancer

    @property
    def page_cache(self):
        return self.session.cache

    @property
    def replica_spread(self) -> bool:
        return self.session.replica_spread

    @replica_spread.setter
    def replica_spread(self, value: bool) -> None:
        self.session.replica_spread = value

    @property
    def sync_write(self) -> bool:
        return self.session.sync_write

    @property
    def max_inflight_writes(self) -> int:
        return self.session.max_inflight_writes

    # -- old entry points -------------------------------------------------------
    def add_data_provider(self) -> int:
        return self.cluster.add_data_provider()

    def alloc(self, size_bytes: int, page_size: int) -> int:
        return self.cluster.alloc(size_bytes, page_size)

    def _handle(self, blob_id: int) -> BlobHandle:
        with self._handles_lock:
            handle = self._handles.get(blob_id)
            if handle is None:
                handle = self._handles[blob_id] = self.session.open(blob_id)
            return handle

    def write(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        return self._handle(blob_id).write(buffer, offset_bytes)

    def writev(
        self, blob_id: int, patches: Sequence[Tuple[int, np.ndarray]]
    ) -> List[int]:
        return self._handle(blob_id).writev(patches)

    def write_async(
        self, blob_id: int, buffer: np.ndarray, offset_bytes: int
    ) -> "Future[int]":
        return self._handle(blob_id).write_async(buffer, offset_bytes)

    def flush(self) -> List[int]:
        return self.session.flush()

    def read(
        self,
        blob_id: int,
        version: Optional[int],
        offset_bytes: int,
        size_bytes: int,
    ) -> ReadResult:
        return self._handle(blob_id).read(offset_bytes, size_bytes, version=version)

    def readv(
        self,
        blob_id: int,
        version: Optional[int],
        segments: Sequence[Tuple[int, int]],
    ) -> List[np.ndarray]:
        return self._handle(blob_id).readv(segments, version=version)

    def write_unaligned(
        self, blob_id: int, buffer: np.ndarray, offset_bytes: int
    ) -> int:
        return self._handle(blob_id).write_unaligned(buffer, offset_bytes)

    def gc(self, blob_id: int, keep_versions: Sequence[int]) -> Tuple[int, int]:
        return self.cluster.gc(blob_id, keep_versions)

    def storage_bytes(self) -> int:
        return self.cluster.storage_bytes()

    def close(self) -> None:
        self.cluster.close()
