"""BlobStore: the paper's client-side access protocol (§III.B).

WRITE(id, buffer, offset, size):
  1. ask the provider manager for placements (one per fresh page);
  2. store pages on the data providers **in parallel**;
  3. ask the version manager for a version number + precomputed border links
     (the only serialized step);
  4. build the new metadata tree and store its nodes on the metadata DHT in
     parallel (weaving happens through the precomputed links — complete
     isolation from concurrent writers);
  5. report success; the version manager publishes versions in order.

READ(id, v, buffer, offset, size):
  1. ask the version manager for the latest published version (fails if the
     requested version is unpublished);
  2. traverse the segment tree of version v over the DHT (parallel per level);
  3. fetch the leaves' pages from the data providers in parallel.

On top of the paper's protocol this client adds two scaling layers that its
immutability guarantees make safe:

* a **versioned page cache** (:mod:`repro.core.page_cache`): pages of
  published versions can never change, so snapshot re-reads hit RAM with no
  invalidation protocol; concurrent cold misses on a page are collapsed into
  one provider fetch (single-flight);
* a **batched multi-segment data plane** — :meth:`BlobStore.readv` /
  :meth:`BlobStore.writev` take many segments, deduplicate shared pages, run
  ONE level-synchronous metadata traversal and ONE aggregated page RPC per
  provider across all segments (the paper's §V.A RPC aggregation, applied
  across an entire vectored request). ``read``/``write``/``write_unaligned``
  are thin wrappers over this plane.

All data-plane steps run on a thread pool to model the paper's concurrent
RPCs; the version manager interaction is the only serialization point.
"""

from __future__ import annotations

import dataclasses
import functools
import random
import threading
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.dht import MetadataDHT, ProviderFailed, TrafficStats
from repro.core.page_cache import PageCache, ZERO_PAGE_CHARGE
from repro.core.provider import DataProvider, ProviderManager
from repro.core.replica_balancer import BalancerConfig, ReplicaBalancer
from repro.core.segment_tree import (
    NodeKey,
    PageRef,
    TreeNode,
    ZERO_VERSION,
    build_write_tree,
    traverse_batch,
)
from repro.core.version_manager import VersionManager

#: Default client page-cache budget (bytes); pass ``cache_bytes=0`` to disable.
DEFAULT_CACHE_BYTES = 64 << 20


@dataclasses.dataclass
class ReadResult:
    latest_published: int
    data: np.ndarray


@functools.lru_cache(maxsize=8)
def _zero_page(page_size: int) -> np.ndarray:
    page = np.zeros(page_size, dtype=np.uint8)
    page.flags.writeable = False
    return page


def _merge_ranges(pages: Sequence[int]) -> List[Tuple[int, int]]:
    """Collapse a sorted page-index list into (offset, size) runs."""
    ranges: List[Tuple[int, int]] = []
    for p in pages:
        if ranges and ranges[-1][0] + ranges[-1][1] == p:
            ranges[-1] = (ranges[-1][0], ranges[-1][1] + 1)
        else:
            ranges.append((p, 1))
    return ranges


class BlobStore:
    """Facade wiring clients to the five actors of the paper's architecture."""

    def __init__(
        self,
        n_data_providers: int = 4,
        n_metadata_providers: int = 4,
        page_replication: int = 1,
        metadata_replication: int = 1,
        max_workers: int = 8,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        replica_spread: bool = True,
        hot_replicas: bool = True,
        balancer_config: Optional[BalancerConfig] = None,
        page_service_seconds: float = 0.0,
    ) -> None:
        self.stats = TrafficStats()
        self.version_manager = VersionManager()
        self.provider_manager = ProviderManager(replication=page_replication, stats=self.stats)
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self.metadata = MetadataDHT(
            n_metadata_providers,
            replication=metadata_replication,
            stats=self.stats,
            executor=self._pool,
        )
        self.page_cache: Optional[PageCache] = (
            PageCache(cache_bytes, stats=self.stats) if cache_bytes else None
        )
        #: pick the least-read-loaded replica per page instead of always the
        #: primary (the knob the skew-read benchmark flips)
        self.replica_spread = replica_spread
        self.page_service_seconds = page_service_seconds
        for i in range(n_data_providers):
            self.provider_manager.register(DataProvider(i, page_service_seconds))
        self.replica_balancer: Optional[ReplicaBalancer] = (
            ReplicaBalancer(
                self.provider_manager, self.metadata, self.stats, balancer_config
            )
            if hot_replicas
            else None
        )
        self._next_provider_id = n_data_providers
        self._membership_lock = threading.Lock()
        self._rng = random.Random(0xB10B)

    # -- elasticity ------------------------------------------------------------
    def add_data_provider(self) -> int:
        with self._membership_lock:
            pid = self._next_provider_id
            self._next_provider_id += 1
        self.provider_manager.register(DataProvider(pid, self.page_service_seconds))
        return pid

    # -- ALLOC -------------------------------------------------------------------
    def alloc(self, size_bytes: int, page_size: int) -> int:
        if page_size & (page_size - 1):
            raise ValueError("page_size must be a power of two")
        if size_bytes % page_size:
            raise ValueError("blob size must be a multiple of page_size")
        total_pages = size_bytes // page_size
        return self.version_manager.alloc(total_pages, page_size)

    # -- WRITE -------------------------------------------------------------------
    def write(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        """Patch ``blob_id`` with ``buffer`` at ``offset_bytes``; returns the
        assigned version (published once all earlier versions publish)."""
        return self.writev(blob_id, [(offset_bytes, buffer)])[0]

    def writev(
        self, blob_id: int, patches: Sequence[Tuple[int, np.ndarray]]
    ) -> List[int]:
        """Vectored WRITE: apply many ``(offset_bytes, buffer)`` page-aligned
        patches. Each patch gets its own version (identical semantics to a
        loop of :meth:`write`, in patch order), but the data plane batches:
        one placement call, ONE aggregated ``put_pages`` RPC per data
        provider across all patches, and one aggregated metadata round per
        shard for all patches' tree nodes. Returns the assigned versions.
        """
        total_pages, page_size = self.version_manager.blob_info(blob_id)
        bufs: List[np.ndarray] = []
        spans: List[Tuple[int, int]] = []  # (page_offset, n_pages) per patch
        for offset_bytes, buffer in patches:
            buffer = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
            if offset_bytes % page_size or buffer.size % page_size:
                raise ValueError("WRITE must be page-aligned (paper §II)")
            n_pages = buffer.size // page_size
            if n_pages == 0:
                raise ValueError("empty write")
            bufs.append(buffer)
            spans.append((offset_bytes // page_size, n_pages))
        if not bufs:
            return []

        # (1) placements for every fresh page of every patch, in one call
        placements = self.provider_manager.allocate(sum(n for _, n in spans))

        # (2) store pages in parallel, ONE aggregated put per provider
        #     covering all patches
        by_provider: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        per_patch: List[List[Tuple[PageRef, Tuple[PageRef, ...]]]] = []
        cursor = 0
        for buffer, (_, n_pages) in zip(bufs, spans):
            mine = placements[cursor : cursor + n_pages]
            cursor += n_pages
            per_patch.append(mine)
            for i, (primary, replicas) in enumerate(mine):
                page = buffer[i * page_size : (i + 1) * page_size].copy()
                for pid, key in (primary,) + replicas:
                    by_provider.setdefault(pid, []).append((key, page))

        def _put(pid: int, items: List[Tuple[int, np.ndarray]]) -> None:
            self.provider_manager.get_provider(pid).put_pages(items)
            self.stats.record_data(pid, len(items), sum(p.nbytes for _, p in items))

        futures = [self._pool.submit(_put, pid, items) for pid, items in by_provider.items()]
        for f in futures:
            f.result()

        # (3) version numbers + border links for ALL patches under ONE manager
        #     lock acquisition (the only serialized step), then (4) ONE
        #     aggregated metadata store for all patches' nodes
        assigned = self.version_manager.assign_versions(blob_id, spans)
        versions: List[int] = [v for v, _ in assigned]
        nodes: List[TreeNode] = []
        for (page_offset, n_pages), mine, (version, links) in zip(
            spans, per_patch, assigned
        ):
            nodes.extend(
                build_write_tree(
                    blob_id, version, total_pages, page_offset, n_pages, mine, links
                )
            )
        self.metadata.put_nodes(nodes)

        # (5) report success → in-order publish
        for version in versions:
            self.version_manager.report_success(blob_id, version)
        return versions

    # -- READ --------------------------------------------------------------------
    def read(
        self,
        blob_id: int,
        version: Optional[int],
        offset_bytes: int,
        size_bytes: int,
    ) -> ReadResult:
        """Read ``[offset_bytes, offset_bytes+size_bytes)`` of ``version``
        (``None`` = latest published). Fails if ``version`` is unpublished or
        the range is fully out of bounds; a range overlapping the blob's end
        is clamped (short read)."""
        total_pages, page_size = self.version_manager.blob_info(blob_id)
        latest = self.version_manager.latest_published(blob_id)
        if version is None:
            version = latest  # resolve once, so the label matches the data
        elif version > latest:
            raise ValueError(f"version {version} not yet published (latest={latest})")
        data = self._readv(
            blob_id, version, [(offset_bytes, size_bytes)], total_pages, page_size
        )[0]
        return ReadResult(latest, data)

    def readv(
        self,
        blob_id: int,
        version: Optional[int],
        segments: Sequence[Tuple[int, int]],
    ) -> List[np.ndarray]:
        """Vectored READ: fetch many ``(offset_bytes, size_bytes)`` segments
        of one version in a single batched pass. Pages shared between
        segments are deduplicated; cache hits skip the network entirely; the
        remaining pages cost one level-synchronous metadata traversal (one
        aggregated RPC per shard per level) plus ONE aggregated ``get_pages``
        RPC per data provider. Returns one ``np.uint8`` array per segment.
        """
        total_pages, page_size = self.version_manager.blob_info(blob_id)
        latest = self.version_manager.latest_published(blob_id)
        if version is None:
            version = latest
        elif version > latest:
            raise ValueError(f"version {version} not yet published (latest={latest})")
        return self._readv(blob_id, version, segments, total_pages, page_size)

    def _readv(
        self,
        blob_id: int,
        version: int,
        segments: Sequence[Tuple[int, int]],
        total_pages: int,
        page_size: int,
    ) -> List[np.ndarray]:
        """``readv`` body with the version-manager state already resolved —
        the serialized actor is consulted exactly once per public call."""
        # clamp segments; collect the deduplicated union of needed pages
        total_bytes = total_pages * page_size
        clamped: List[Tuple[int, int]] = []
        needed: Set[int] = set()
        for offset, size in segments:
            if offset < 0 or size < 0:
                raise ValueError(f"negative read offset/size ({offset}, {size})")
            if size == 0:
                clamped.append((offset, 0))
                continue
            if offset >= total_bytes:
                raise ValueError(
                    f"read at offset {offset} out of range (blob is {total_bytes} bytes)"
                )
            size = min(size, total_bytes - offset)  # clamp to blob end
            clamped.append((offset, size))
            first_page = offset // page_size
            last_page = min(-(-(offset + size) // page_size), total_pages)
            needed.update(range(first_page, last_page))

        # cache phase: hits are served from RAM; exactly one concurrent
        # reader becomes the fetch leader for each missing page
        pages: Dict[int, Optional[np.ndarray]] = {}
        cache = self.page_cache
        owned: List[int] = []
        waits: Dict[Tuple[int, int, int], object] = {}
        if cache is not None and needed:
            plan = cache.plan([(blob_id, version, p) for p in sorted(needed)])
            pages.update({key[2]: page for key, page in plan.hits.items()})
            owned = sorted(key[2] for key in plan.owned)
            waits = plan.waits
        else:
            owned = sorted(needed)

        if owned:
            fulfilled: Set[int] = set()
            try:
                # (2) ONE metadata traversal pass over all missed ranges
                leaves = traverse_batch(
                    self.metadata.get_nodes, blob_id, version, total_pages,
                    _merge_ranges(owned),
                )
                # (3) ONE aggregated page fetch per provider
                fetched = self._fetch_pages(leaves, page_size)
                for p, page in fetched.items():
                    pages[p] = page
                    if cache is not None:
                        # zero pages share one buffer — charge them the LRU
                        # slot, not a full page, so repeat sparse reads skip
                        # the metadata walk without evicting real pages
                        cache.fulfill(
                            (blob_id, version, p),
                            page if page is not None else _zero_page(page_size),
                            charge=None if page is not None else ZERO_PAGE_CHARGE,
                        )
                        fulfilled.add(p)
            except BaseException as err:
                if cache is not None:
                    for p in owned:
                        if p not in fulfilled:
                            cache.abort((blob_id, version, p), err)
                raise

        # follower phase: collect pages fetched by concurrent leaders
        for key, flight in waits.items():
            pages[key[2]] = cache.wait(key, flight)  # type: ignore[union-attr, arg-type]

        # assemble per-segment outputs from the shared page map
        outs: List[np.ndarray] = []
        for offset, size in clamped:
            out = np.zeros(size, dtype=np.uint8)
            for p in range(offset // page_size, -(-(offset + size) // page_size)):
                page = pages.get(p)
                if page is None:
                    continue  # implicit zero page
                page_lo = p * page_size
                a = max(offset, page_lo)
                b = min(offset + size, page_lo + page_size)
                out[a - offset : b - offset] = page[a - page_lo : b - page_lo]
            outs.append(out)
        return outs

    def _choose_ref(
        self, leaf: TreeNode, read_load: Dict[int, int], page_size: int
    ) -> PageRef:
        """Pick which replica serves this page via power-of-two random
        choices: sample two replicas, take the one with less read traffic so
        far, charging ``read_load`` tentatively so one batch also spreads.
        The random sampling is what prevents the herd effect — a
        deterministic global minimum sends every concurrent client to the
        same momentarily-idle provider, re-serializing the hot page there."""
        refs = leaf.all_page_refs()
        a, b = self._rng.sample(range(len(refs)), 2)
        pid, key = min(
            refs[a], refs[b], key=lambda r: read_load.get(r[0], 0)
        )
        read_load[pid] = read_load.get(pid, 0) + page_size
        return pid, key

    def _fetch_pages(
        self, leaves: Dict[int, Optional[TreeNode]], page_size: int
    ) -> Dict[int, Optional[np.ndarray]]:
        """Fetch all leaf pages: one aggregated RPC per serving provider (in
        parallel), per-page replica fallback if a provider batch fails. The
        serving provider per page is replica-spread (least read load) rather
        than always the primary, and every provider fetch feeds the replica
        balancer's heat counters."""
        result: Dict[int, Optional[np.ndarray]] = {}
        by_provider: Dict[int, List[Tuple[int, int, TreeNode]]] = defaultdict(list)
        # stats snapshot is deferred until a leaf actually has a choice to
        # make — single-replica reads must not pay a global-lock round-trip
        read_load: Optional[Dict[int, int]] = None
        for page_index, leaf in leaves.items():
            if leaf is None:
                result[page_index] = None  # implicit zero page
                continue
            if self.replica_spread and len(leaf.all_page_refs()) > 1:
                if read_load is None:
                    read_load = self.stats.read_bytes_snapshot()
                pid, key = self._choose_ref(leaf, read_load, page_size)
            else:
                pid, key = leaf.page  # type: ignore[misc]
            by_provider[pid].append((page_index, key, leaf))

        def _get_batch(
            pid: int, items: List[Tuple[int, int, TreeNode]]
        ) -> Optional[Dict[int, np.ndarray]]:
            try:
                provider = self.provider_manager.get_provider(pid)
                fetched = provider.get_pages([key for _, key, _ in items])
            except (ProviderFailed, KeyError):
                return None  # provider down/deregistered: caller falls back
            self.stats.record_data(
                pid, len(items), sum(pg.nbytes for pg in fetched), read=True
            )
            return {p: pg for (p, _, _), pg in zip(items, fetched)}

        batches = list(by_provider.items())
        futures = [self._pool.submit(_get_batch, pid, items) for pid, items in batches]
        fallback: List[Tuple[int, TreeNode, int]] = []
        for (pid, items), f in zip(batches, futures):
            got = f.result()
            if got is None:
                fallback.extend((p, leaf, pid) for p, _, leaf in items)
            else:
                result.update(got)
        if fallback:
            # replica fallback in parallel, skipping the observed-dead choice
            fb = [
                self._pool.submit(self._fetch_single, p, leaf, skip)
                for p, leaf, skip in fallback
            ]
            for (p, _, _), f in zip(fallback, fb):
                result[p] = f.result()
        if self.replica_balancer is not None:
            self.replica_balancer.note_fetches(
                items[2] for batch in by_provider.values() for items in batch
            )
        return result

    def _fetch_single(
        self, page_index: int, leaf: TreeNode, skip_pid: Optional[int] = None
    ) -> np.ndarray:
        refs = [r for r in leaf.all_page_refs() if r[0] != skip_pid]
        last_err: Optional[Exception] = None
        for pid, key in refs or leaf.all_page_refs():
            try:
                page = self.provider_manager.get_provider(pid).get_page(key)
                self.stats.record_data(pid, 1, page.nbytes, read=True)
                return page
            except (ProviderFailed, KeyError) as err:
                last_err = err
        raise last_err if last_err else KeyError(f"page {page_index} unavailable")

    def write_unaligned(self, blob_id: int, buffer: np.ndarray, offset_bytes: int) -> int:
        """WRITE at arbitrary byte offset/size via client-side read-modify-write
        of the boundary pages (the paper's API allows arbitrary segments; pages
        are the storage granularity, so partial boundary pages are merged from
        the latest published version before patching). Both boundary pages are
        fetched in one :meth:`readv` call, so hot boundary pages come straight
        from the page cache.

        Note the concurrency caveat the paper implies: the boundary merge reads
        the LATEST version, so two concurrent unaligned writers sharing a
        boundary page serialize at page granularity like any COW system.
        """
        _, page_size = self.version_manager.blob_info(blob_id)
        buffer = np.ascontiguousarray(buffer).view(np.uint8).reshape(-1)
        lo = offset_bytes // page_size * page_size
        hi = -(-(offset_bytes + buffer.size) // page_size) * page_size
        if lo == offset_bytes and hi == offset_bytes + buffer.size:
            return self.write(blob_id, buffer, offset_bytes)
        merged = np.zeros(hi - lo, np.uint8)
        boundary_segs: List[Tuple[int, int]] = []
        if lo < offset_bytes:  # left boundary page
            boundary_segs.append((lo, page_size))
        if hi > offset_bytes + buffer.size:  # right boundary page
            boundary_segs.append((hi - page_size, page_size))
        boundary = self.readv(blob_id, None, boundary_segs)
        for (seg_off, _), data in zip(boundary_segs, boundary):
            merged[seg_off - lo : seg_off - lo + page_size] = data
        merged[offset_bytes - lo : offset_bytes - lo + buffer.size] = buffer
        return self.write(blob_id, merged, lo)

    # -- GC (paper future work) -----------------------------------------------------
    def gc(self, blob_id: int, keep_versions: Sequence[int]) -> Tuple[int, int]:
        """Drop all tree nodes / pages unreachable from ``keep_versions``.

        Must be invoked only when no concurrent accesses target the dropped
        versions (the paper's "ordered by the client" semantics). Cached pages
        of dropped versions are purged as well. Promotion passes are paused
        for the duration — an in-flight promotion could otherwise re-create a
        just-deleted leaf node or copy a page GC is about to drop. Returns
        (nodes_freed, pages_freed).
        """
        if self.replica_balancer is not None:
            with self.replica_balancer.paused():
                return self._gc_locked(blob_id, keep_versions)
        return self._gc_locked(blob_id, keep_versions)

    def _gc_locked(self, blob_id: int, keep_versions: Sequence[int]) -> Tuple[int, int]:
        total_pages, _ = self.version_manager.blob_info(blob_id)
        latest = self.version_manager.latest_published(blob_id)
        keep = sorted(set(v for v in keep_versions if v != ZERO_VERSION))
        reachable_nodes: Set[NodeKey] = set()
        reachable_pages: Set[PageRef] = set()

        def mark(version: int, offset: int, size: int) -> None:
            if version == ZERO_VERSION:
                return
            key = NodeKey(blob_id, version, offset, size)
            if key in reachable_nodes:
                return
            node = self.metadata.get_node(key)
            reachable_nodes.add(key)
            if node.is_leaf:
                reachable_pages.update(node.all_page_refs())
                return
            half = size // 2
            mark(node.left_version, offset, half)
            mark(node.right_version, offset + half, half)

        for v in keep:
            mark(v, 0, total_pages)

        # Enumerate every stored node of this blob and drop unreachable ones.
        doomed_nodes: List[NodeKey] = []
        doomed_pages: Set[PageRef] = set()
        for key, node in self.metadata.iter_nodes(blob_id):
            if key.version > latest:
                continue  # never GC in-flight (unpublished) versions
            if key not in reachable_nodes:
                doomed_nodes.append(key)
                if node.is_leaf:
                    doomed_pages.update(ref for ref in node.all_page_refs())
        doomed_pages -= reachable_pages
        self.metadata.delete_nodes(doomed_nodes)
        if self.replica_balancer is not None:
            # demote-on-GC: the promoted copies die with the doomed leaves
            # (they are in the rewritten nodes' all_page_refs above); drop the
            # balancer's heat/promotion records so they can't be re-targeted
            self.replica_balancer.forget(doomed_nodes)
        by_provider: Dict[int, List[int]] = {}
        for pid, key in doomed_pages:
            by_provider.setdefault(pid, []).append(key)
        for pid, keys in by_provider.items():
            self.provider_manager.get_provider(pid).delete_pages(keys)
        self.provider_manager.release(sorted(doomed_pages))
        if self.page_cache is not None:
            self.page_cache.drop_versions(blob_id, set(keep) | {ZERO_VERSION})
        return len(doomed_nodes), len(doomed_pages)

    # -- introspection ------------------------------------------------------------
    def storage_bytes(self) -> int:
        return sum(p.used_bytes() for p in self.provider_manager.providers())

    def close(self) -> None:
        self.metadata.close()
        self._pool.shutdown(wait=True)
