"""Background re-replication and metadata scrub: the self-healing half of
the data plane.

The health machine in :class:`~repro.core.provider.ProviderManager` turns
observed RPC failures into a ``live → suspect → dead`` verdict; this module
is what happens *after* the verdict. When a provider is declared dead its
published pages are down one replica — readers still complete through the
surviving copies (the read plane's per-page fallback), but the cluster is
running degraded until someone restores the replication factor. The
:class:`RepairService` is that someone:

* **Re-replication** (:meth:`RepairService.run_once`): for every published
  leaf with a replica on a dead (or failure-flagged) provider, copy the page
  from a surviving replica onto healthy providers until ``replication``
  copies exist again, then re-put the leaf with the corrected ref set — the
  same sanctioned placement-only leaf rewrite the replica balancer performs,
  serialized on the same lock.
* **Metadata re-replication** (part of :meth:`RepairService.run_once`): the
  same treatment for the metadata plane. When a metadata shard dies
  (``MetadataDHT.on_dead`` is wired to :meth:`schedule`, exactly like the
  provider hook) its node copies are down one replica; once the shard — or
  a blank stand-in — rejoins, the pass rebuilds its journal-covered node
  set from the surviving consecutive-home replicas via
  :meth:`~repro.core.dht.MetadataDHT.restore_replication`. Create-only
  nodes make any survivor an authoritative source.
* **Metadata scrub** (:meth:`RepairService.scrub`): writer recovery. A
  writer that died mid-``writev`` was withdrawn by
  :meth:`~repro.core.version_manager.VersionManager.abandon`; if it had
  become a publication *hole*, later published versions may carry border
  links into trees the hole never (fully) stored. Readers survive those
  dangling links through the version manager's redirect
  (:meth:`~repro.core.version_manager.VersionManager.redirect_read_link`),
  but the wreckage — partial nodes, orphan pages, phantom placement load —
  stays behind. The scrub rewrites every inner link that points into an
  aborted version to its redirect target and deletes the hole's stored
  nodes and pages, returning their placement credit. Abandons are
  journaled, so a recovered version manager replays the same holes and the
  scrub remains valid after recovery.

Both passes run under ONE level-2 lock: on clusters with a replica balancer
the service *aliases* ``ReplicaBalancer._rebalance_lock`` (repair, promotion
and GC exclusion serialize together — GC pausing the balancer pauses repair
for free); without a balancer it constructs its own declared
``RepairService._lock`` at the same level and :meth:`Cluster.gc` pauses
repair through :meth:`RepairService.paused`.

Scheduling: ``ProviderManager.on_dead`` (fired outside the manager lock) is
wired to :meth:`RepairService.schedule`, which queues one pass on the
cluster's aux pool — repair never steals a data-plane worker, and a flurry
of death verdicts coalesces into one pass.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.lockwatch import make_lock
from repro.core.dht import ProviderFailed, page_checksum
from repro.core.segment_tree import NodeKey, PageRef, TreeNode

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (cluster imports us)
    from repro.core.cluster import Cluster


class RepairService:
    """Restores the replication factor and scrubs abandoned-write wreckage.

    Construct once per cluster (done by ``Cluster.__init__``); thread-safe.
    ``run_once``/``scrub`` may be called directly (tests, admin tooling) or
    arrive via :meth:`schedule` on the aux pool.
    """

    def __init__(self, cluster: "Cluster") -> None:
        self.cluster = cluster
        balancer = cluster.replica_balancer
        #: level-2 pass lock; aliases the balancer's rebalance lock when the
        #: balancer exists so repair/promotion/GC-exclusion serialize on one
        #: lock (see lock_order.py — the two NAMES must never nest)
        if balancer is not None:
            self._lock = balancer._rebalance_lock
        else:
            self._lock = make_lock("RepairService._lock")
        #: best-effort dedup for schedule(): a benign race (two schedulers
        #: both passing the check) just queues one redundant no-op pass
        self._queued = False
        #: last background-pass failure, kept observable (aux-pool futures
        #: are fire-and-forget)
        self.last_error: Optional[BaseException] = None
        #: total page copies re-replicated by this service
        self.pages_repaired = 0
        #: total nodes scrubbed (hole nodes deleted + inner links rewritten)
        self.nodes_scrubbed = 0
        #: total metadata node copies re-replicated onto recovered shards
        self.nodes_rereplicated = 0

    # -- scheduling ----------------------------------------------------------
    def schedule(self, provider_id: Optional[int] = None) -> None:
        """Queue one repair pass on the cluster's aux pool (the
        ``ProviderManager.on_dead`` hook). Death verdicts arriving while a
        pass is queued coalesce — the pass snapshots the dead set when it
        runs, so it covers them all. Never raises: a closed cluster simply
        drops the pass."""
        if self._queued:
            return
        self._queued = True
        try:
            self.cluster._aux_submit(self._run_background)
        except RuntimeError:  # cluster closed: nothing left to repair
            self._queued = False

    def _run_background(self) -> None:
        self._queued = False  # re-arm BEFORE running: a death verdict that
        # lands mid-pass must queue a fresh pass for the state it changed
        try:
            self.run_once()
        except BaseException as err:  # noqa: BLE001 - keep the aux pool alive
            self.last_error = err

    # -- GC interlock --------------------------------------------------------
    @contextlib.contextmanager
    def paused(self) -> Iterator[None]:
        """Block repair/scrub passes for the duration. ``Cluster.gc`` uses
        this on balancer-less clusters; with a balancer, pausing the
        balancer pauses repair too (same underlying lock)."""
        with self._lock:
            yield

    # -- re-replication ------------------------------------------------------
    def run_once(self, scrub: bool = True) -> Tuple[int, int]:
        """One full repair pass over every blob: re-replicate published
        leaves that lost copies to dead/failed providers, (by default)
        scrub abandoned-write wreckage, then restore metadata replication
        for journal-covered nodes (tracked in :attr:`nodes_rereplicated`).
        Returns ``(pages_repaired, nodes_scrubbed)`` for this pass.

        Pages whose every replica is unreachable are *unrepairable* and
        skipped — with ``replication`` copies that takes ``replication``
        simultaneous deaths, the same bound any replicated store carries.
        Stale pages left on a provider that later recovers are orphans until
        :meth:`Cluster.gc` collects them (their leaves no longer reference
        that provider)."""
        with self._lock:
            repaired = 0
            scrubbed = 0
            rereplicated = 0
            vm = self.cluster.version_manager
            for blob_id in vm.blob_ids():
                repaired += self._repair_blob_locked(blob_id)
                if scrub:
                    scrubbed += self._scrub_blob_locked(blob_id)
                rereplicated += self._restore_metadata_locked(blob_id)
            self.pages_repaired += repaired
            self.nodes_scrubbed += scrubbed
            self.nodes_rereplicated += rereplicated
            return repaired, scrubbed

    def _unavailable_pids(self) -> Set[int]:
        pm = self.cluster.provider_manager
        down = set(pm.dead_providers())
        for provider in pm.providers():
            if provider.failed:
                down.add(provider.provider_id)
        return down

    def _repair_blob_locked(self, blob_id: int) -> int:
        pm = self.cluster.provider_manager
        vm = self.cluster.version_manager
        metadata = self.cluster.metadata
        down = self._unavailable_pids()
        if not down:
            return 0
        published, aborted = vm.repair_horizon(blob_id)
        corrected: List[TreeNode] = []
        released: List[PageRef] = []
        repaired = 0
        for key, node in metadata.iter_nodes(blob_id):
            if not node.is_leaf:
                continue
            if key.version > published or key.version in aborted:
                continue  # in-flight writers fix their own placements
            refs = node.all_page_refs()
            lost = [r for r in refs if r[0] in down]
            if not lost:
                continue
            survivors = [r for r in refs if r[0] not in down]
            if not survivors:
                continue  # every replica down at once: unrepairable
            page = self._fetch_from_survivors(survivors, node.checksum)
            holders = {r[0] for r in refs}
            fresh: List[PageRef] = []
            if page is not None:
                want = max(pm.replication - len(survivors), 0)
                for _ in range(want):
                    placed = self._place_copy(page, survivors[0][1], holders)
                    if placed is None:
                        break  # out of healthy capacity; drop lost refs anyway
                    holders.add(placed[0])
                    fresh.append(placed)
                repaired += len(fresh)
            # rewrite the leaf without the lost refs even when no fresh copy
            # could be placed — readers must stop dialing dead providers
            new_refs = survivors + fresh
            corrected.append(
                dataclasses.replace(
                    node, page=new_refs[0], replicas=tuple(new_refs[1:])
                )
            )
            released.extend(lost)
        if corrected:
            metadata.put_nodes(corrected)
            pm.release(released)
        if repaired:
            self.cluster.stats.record_repair(repaired)
        return repaired

    def _fetch_from_survivors(
        self, survivors: List[PageRef], checksum: Optional[int] = None
    ):
        """First *verified* copy among the survivors: a fetch whose bytes do
        not match the leaf's freeze-time checksum is silent corruption, not a
        repair source — it is skipped (and counted) like a failed provider."""
        pm = self.cluster.provider_manager
        for pid, page_key in survivors:
            try:
                page = pm.get_provider(pid).get_page(page_key)
            except ProviderFailed:
                pm.note_failure(pid)
                continue
            except KeyError:
                continue
            if checksum is not None and page_checksum(page) != checksum:
                self.cluster.stats.record_checksum_failure()
                pm.note_failure(pid)
                continue
            pm.note_success(pid)
            return page
        return None

    def _place_copy(
        self, page, page_key: int, holders: Set[int]
    ) -> Optional[PageRef]:
        """Copy ``page`` (stored under ``page_key``) onto the least-loaded
        healthy provider not already holding it; returns the new ref or
        ``None`` when no target qualifies."""
        pm = self.cluster.provider_manager
        tried: Set[int] = set()
        while True:
            target = pm.least_loaded(exclude=tuple(holders | tried))
            if target is None:
                return None
            try:
                pm.get_provider(target).put_pages([(page_key, page)])
            except ProviderFailed:
                pm.note_failure(target)
                tried.add(target)
                continue
            except KeyError:
                tried.add(target)
                continue
            pm.note_success(target)
            pm.add_load(target, 1)
            return (target, page_key)

    # -- metadata re-replication ---------------------------------------------
    def _restore_metadata_locked(self, blob_id: int) -> int:
        """Rebuild a dead/recovered metadata replica's node set from the
        surviving replicas: every journal-covered node (at or below the
        publish frontier, not an abandoned hole) is re-put to any of its
        ``metadata_replication`` consecutive home shards that lost it. The
        node store is create-only, so re-putting from ANY survivor is sound
        — there is nothing newer a dead replica could have held for these
        keys. Runs under the same level-2 pass lock as page repair and the
        scrub, so a scrub deleting hole nodes never races a pass restoring
        them."""
        metadata = self.cluster.metadata
        if metadata.replication <= 1:
            return 0
        published, aborted = self.cluster.version_manager.repair_horizon(
            blob_id
        )
        covered: List[TreeNode] = []
        for key, node in metadata.iter_nodes(blob_id):
            if key.version > published or key.version in aborted:
                continue  # outside the journal-covered horizon
            covered.append(node)
        if not covered:
            return 0
        return metadata.restore_replication(covered)

    # -- writer recovery (dead node) -----------------------------------------
    def recover_writers(self, sessions) -> int:
        """Scrub after a *node* death (federated mode): every session of the
        dead node may hold assigned-but-unreported versions that would wedge
        in-order publication forever. Abandon them (erase or hole, per
        :meth:`VersionManager.abandon`), then scrub the holes' wreckage so
        the storage space comes back. Idempotent — versions the writer
        already aborted itself are skipped by ``abandon``. Returns the
        number of versions abandoned."""
        vm = self.cluster.version_manager
        doomed: Dict[int, Set[int]] = {}
        for sess in sessions:
            for blob_id, versions in sess.inflight_versions().items():
                doomed.setdefault(blob_id, set()).update(versions)
        abandoned = 0
        for blob_id, versions in doomed.items():
            vm.abandon(blob_id, sorted(versions))
            abandoned += len(versions)
            self.scrub(blob_id)
        return abandoned

    # -- metadata scrub (writer recovery) ------------------------------------
    def scrub(self, blob_id: int) -> int:
        """Scrub one blob's abandoned-write wreckage; see module docstring.
        Returns nodes scrubbed (holes deleted + inner links rewritten)."""
        with self._lock:
            n = self._scrub_blob_locked(blob_id)
            self.nodes_scrubbed += n
            return n

    def _scrub_blob_locked(self, blob_id: int) -> int:
        vm = self.cluster.version_manager
        pm = self.cluster.provider_manager
        metadata = self.cluster.metadata
        aborted = vm.aborted_view(blob_id)
        if not aborted:
            return 0
        doomed: List[NodeKey] = []
        doomed_pages: Set[PageRef] = set()
        rewritten: List[TreeNode] = []
        for key, node in metadata.iter_nodes(blob_id):
            if key.version in aborted:
                # wreckage the abort left behind (partial puts of a hole)
                doomed.append(key)
                if node.is_leaf:
                    doomed_pages.update(node.all_page_refs())
                continue
            if node.is_leaf:
                continue
            left, right = node.left_version, node.right_version
            if left not in aborted and right not in aborted:
                continue
            half = key.size // 2
            if left in aborted:
                left = vm.redirect_read_link(blob_id, left, key.offset, half)
            if right in aborted:
                right = vm.redirect_read_link(
                    blob_id, right, key.offset + half, half
                )
            rewritten.append(
                dataclasses.replace(node, left_version=left, right_version=right)
            )
        if rewritten:
            # unlink FIRST: once no stored link reaches the holes, deleting
            # their nodes cannot strand a concurrent traversal (which also
            # redirects on its own via the aborted view)
            metadata.put_nodes(rewritten)
        if doomed:
            metadata.delete_nodes(doomed)
            by_provider: Dict[int, List[int]] = {}
            for pid, page_key in doomed_pages:
                by_provider.setdefault(pid, []).append(page_key)
            for pid, page_keys in by_provider.items():
                try:  # best-effort: a down provider keeps orphans until GC
                    pm.get_provider(pid).delete_pages(page_keys)
                except (ProviderFailed, KeyError):
                    pass
            pm.release(sorted(doomed_pages))
        return len(doomed) + len(rewritten)
