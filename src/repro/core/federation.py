"""Federated multi-node clusters over one shared substrate (paper §VI).

The paper's deployment is many *access nodes* — each running its own client
sessions, page-cache tier and prefetchers — over one shared infrastructure:
the version manager (still the system's only serialization point), the
metadata DHT and the data providers. :class:`Federation` builds exactly that
topology in-process: N :class:`~repro.core.cluster.Cluster` nodes constructed
around ONE injected ``VersionManager``/``ProviderManager``/``MetadataDHT``,
each keeping its own shared cache tier and session population.

The robustness core is the **GC epoch/lease protocol**
(:class:`GcEpochCoordinator`), the missing distributed half of GC↔cache
coherence. Single-node GC can purge every cache on its node inline; a
federated GC pass cannot reach into a partitioned node's RAM, so reclaiming
storage is only safe once every remote cache is provably incapable of
serving the reclaimed versions:

* every node holds a **time-bounded, renewable lease** tied to the GC epoch
  it last joined;
* ``Federation.gc`` advances the epoch and, per live node, obtains an **ack**
  — the node's cache tiers are purged of the collected versions and the node
  rejoins at the new epoch — retrying per :class:`RetryPolicy`;
* a node whose ack cannot be obtained is **waited out**: its lease expiry
  bounds the stall (recorded in ``TrafficStats.epoch_stalls``), because
* a node whose lease lapses **fences itself**: the per-read lease guard
  purges its tiers (``TrafficStats.lease_fences``) and refuses every
  frontier-validated cache serve — reads fall through to the providers,
  which is always correct — until the node rejoins at the *current* epoch.
  A lease renewal that discovers the epoch advanced underneath it (the
  renew-under-GC race) fences and rejoins the same way, which *is* the ack
  the GC pass is waiting for.

The invariant that makes remote caches trustworthy: **no node ever serves a
cached page of a reclaimed version after its lease expired** — reclaim
happens only after ack-or-expiry, and expiry forces the fence before the
next cache serve.

Node liveness reuses the ``live → suspect → dead`` health machine of the
provider/metadata planes (same :class:`HealthConfig`, same sliding-window
rules): failed ack RPCs feed it, and a node declared **dead** has its lease
and coordinator pins reclaimed and its sessions' assigned-but-unreported
versions abandoned via :meth:`RepairService.recover_writers`, so in-order
publication never wedges behind a dead writer. Snapshot pins are federated
too: every node forwards pins to the coordinator (a partitioned node's pin
is *refused* — the safe failure), so a GC initiated on any node honors
every live node's snapshots; only a death verdict reclaims them.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.lockwatch import make_condition, make_lock
from repro.core.cluster import DEFAULT_SHARED_CACHE_BYTES, Cluster
from repro.core.dht import (
    HealthConfig,
    MetadataDHT,
    ProviderFailed,
    RetryPolicy,
    TrafficStats,
)
from repro.core.provider import DataProvider, ProviderManager
from repro.core.segment_tree import ZERO_VERSION
from repro.core.version_manager import VersionManager

#: node modes (the chaos harness's node plane drives these)
NODE_UP = "up"
#: coordinator RPCs fail, the data plane still works — the fencing story
NODE_PARTITIONED = "partitioned"
#: every RPC in or out fails, but the process is "alive" (hung)
NODE_WEDGED = "wedged"
#: the node is gone
NODE_KILLED = "killed"


class GcEpochCoordinator:
    """Epoch counter + per-node leases + federated snapshot pins.

    All state lives under ONE level-3 lock; no method blocks while holding
    it except :meth:`pin`, which waits on the aliased condition while a GC
    sweep is in progress (the federated analog of the single-node
    ``_gc_guard`` pin linearization — a pin lands strictly before the sweep
    reads the pin set, or strictly after the sweep completes).

    Lease semantics:

    * :meth:`join` grants a fresh lease (``lease_seconds`` long on the
      injectable ``clock``) bound to the *current* epoch — callers must
      purge their cache tiers BEFORE joining a newer epoch;
    * :meth:`renew` extends the lease only while the epoch still matches:
      a renewal under an advanced epoch fails, forcing the fence+rejoin
      that doubles as the GC ack;
    * :meth:`reclaim` (the death path) drops the lease AND the node's pins.

    Node health mirrors :class:`~repro.core.provider.ProviderManager`'s
    machine exactly: failures inside the sliding window make a node
    ``suspect`` then ``dead`` (sticky until success or :meth:`revive`).
    """

    def __init__(
        self,
        lease_seconds: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
        health: Optional[HealthConfig] = None,
    ) -> None:
        self.lease_seconds = lease_seconds
        self.clock = clock
        self.health_config = health or HealthConfig()
        self._lock = make_lock("GcEpochCoordinator._lock")
        self._cv = make_condition("GcEpochCoordinator._cv", lock=self._lock)
        self._epoch = 1
        #: node -> epoch it last joined at
        self._lease_epoch: Dict[int, int] = {}
        #: node -> absolute lease expiry on ``clock``
        self._lease_expiry: Dict[int, float] = {}
        #: node -> (blob_id, version) -> refcount (reclaimed on node death)
        self._pins: Dict[int, Dict[Tuple[int, int], int]] = {}
        #: node health: failure timestamps within the window + sticky deaths
        self._failures: Dict[int, List[float]] = {}
        self._dead: Set[int] = set()
        #: a GC storage sweep is in progress: pins wait it out
        self._sweeping = False

    # -- epoch / leases ------------------------------------------------------
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def advance_epoch(self) -> int:
        with self._lock:
            self._epoch += 1
            return self._epoch

    def join(self, node_id: int) -> int:
        """Grant ``node_id`` a fresh lease at the current epoch and return
        that epoch. The caller must have purged its cache tiers first when
        it is joining a newer epoch than it last held."""
        with self._lock:
            if node_id in self._dead:
                raise ProviderFailed(
                    f"node {node_id} is declared dead; revive it first"
                )
            self._lease_epoch[node_id] = self._epoch
            self._lease_expiry[node_id] = self.clock() + self.lease_seconds
            return self._epoch

    def renew(self, node_id: int) -> bool:
        """Extend the lease; ``False`` when the node must fence+rejoin
        instead (epoch advanced under it, lease already expired, or a death
        verdict stands)."""
        with self._lock:
            if node_id in self._dead:
                return False
            if self._lease_epoch.get(node_id) != self._epoch:
                return False  # renew-under-GC: rejoining is the ack
            if self._lease_expiry.get(node_id, 0.0) <= self.clock():
                return False
            self._lease_expiry[node_id] = self.clock() + self.lease_seconds
            return True

    def lease_valid(self, node_id: int) -> bool:
        with self._lock:
            return (
                node_id not in self._dead
                and self._lease_expiry.get(node_id, 0.0) > self.clock()
            )

    def seconds_until_expiry(self, node_id: int) -> float:
        with self._lock:
            return max(
                0.0, self._lease_expiry.get(node_id, 0.0) - self.clock()
            )

    def joined_epoch(self, node_id: int) -> Optional[int]:
        with self._lock:
            return self._lease_epoch.get(node_id)

    def reclaim(self, node_id: int) -> None:
        """Death path: the node's lease AND its pins die with it."""
        with self._lock:
            self._lease_epoch.pop(node_id, None)
            self._lease_expiry.pop(node_id, None)
            self._pins.pop(node_id, None)

    # -- federated snapshot pins ---------------------------------------------
    def pin(self, node_id: int, blob_id: int, version: int) -> None:
        """Register a snapshot pin for ``node_id``. Blocks while a GC sweep
        is in progress — the pin then lands strictly after the pass (whose
        reclaim it could no longer veto), never mid-sweep."""
        with self._cv:
            while self._sweeping:
                self._cv.wait()
            if node_id in self._dead:
                raise ProviderFailed(
                    f"node {node_id} is declared dead; pin refused"
                )
            pins = self._pins.setdefault(node_id, {})
            key = (blob_id, version)
            pins[key] = pins.get(key, 0) + 1

    def unpin(self, node_id: int, blob_id: int, version: int) -> None:
        with self._lock:
            pins = self._pins.get(node_id)
            if not pins:
                return
            key = (blob_id, version)
            if key not in pins:
                return
            pins[key] -= 1
            if pins[key] <= 0:
                del pins[key]
            if not pins:
                del self._pins[node_id]

    def sync_pins(
        self, node_id: int, pins: Dict[Tuple[int, int], int]
    ) -> None:
        """Rejoin-time resync: replace ``node_id``'s registered pins with
        the node's local pin table. Unpins issued while the node was
        unreachable are swallowed best-effort on the node side, so without
        this the coordinator would protect the released versions forever;
        conversely a revived node re-registers the pins its death verdict
        reclaimed. Blocks while a sweep is in progress, like :meth:`pin` —
        re-added pins land strictly after the pass they could no longer
        veto."""
        with self._cv:
            while self._sweeping:
                self._cv.wait()
            if pins:
                self._pins[node_id] = dict(pins)
            else:
                self._pins.pop(node_id, None)

    def pinned_versions(self, blob_id: int) -> Set[int]:
        """Union of every node's pins for ``blob_id`` — what a federated GC
        pass must keep no matter what the caller asked for."""
        with self._lock:
            return {
                v
                for pins in self._pins.values()
                for (b, v) in pins
                if b == blob_id
            }

    def begin_sweep(self, blob_id: int) -> Set[int]:
        """Open the sweep window: returns the pin snapshot for ``blob_id``
        and blocks new pins until :meth:`end_sweep`."""
        with self._lock:
            self._sweeping = True
            return {
                v
                for pins in self._pins.values()
                for (b, v) in pins
                if b == blob_id
            }

    def end_sweep(self) -> None:
        with self._cv:
            self._sweeping = False
            self._cv.notify_all()

    # -- node health (live -> suspect -> dead) --------------------------------
    def note_failure(self, node_id: int) -> bool:
        """Record a failed coordinator RPC against ``node_id``; returns True
        exactly once, when the failure crosses the death threshold (the
        caller runs the death path — reclaim + writer recovery — outside
        this lock)."""
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        with self._lock:
            record = self._failures.setdefault(node_id, [])
            record.append(now)
            while record and record[0] < horizon:
                record.pop(0)
            if (
                len(record) >= self.health_config.dead_after
                and node_id not in self._dead
            ):
                self._dead.add(node_id)
                return True
            return False

    def note_success(self, node_id: int) -> None:
        with self._lock:
            self._failures.pop(node_id, None)
            self._dead.discard(node_id)

    def node_dead(self, node_id: int) -> bool:
        with self._lock:
            return node_id in self._dead

    def health_state(self, node_id: int) -> str:
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        with self._lock:
            if node_id in self._dead:
                return "dead"
            record = self._failures.get(node_id)
            if not record:
                return "live"
            recent = sum(1 for t in record if t >= horizon)
            return (
                "suspect"
                if recent >= self.health_config.suspect_after
                else "live"
            )

    def revive(self, node_id: int) -> None:
        """Rejoin announcement: clear the health record and death verdict
        (the caller purges the node's tiers and :meth:`join`\\ s it)."""
        with self._lock:
            self._failures.pop(node_id, None)
            self._dead.discard(node_id)


class Federation:
    """N access nodes over one shared substrate, with epoch/lease GC.

    ``nodes[0]`` is the *home* node: it hosts the one wired
    :class:`~repro.core.repair.RepairService` (per-node repair passes over a
    shared substrate would race each other) and runs the storage sweep of a
    federated GC pass. Every node is a full :class:`Cluster` — sessions,
    private + shared cache tiers, prefetchers — whose GC, snapshot-pin and
    cache-serve paths are rewired through this federation.
    """

    def __init__(
        self,
        n_nodes: int = 2,
        n_data_providers: int = 4,
        n_metadata_providers: int = 4,
        page_replication: int = 1,
        metadata_replication: int = 1,
        max_workers: int = 8,
        shared_cache_bytes: int = DEFAULT_SHARED_CACHE_BYTES,
        page_service_seconds: float = 0.0,
        metadata_latency_seconds: float = 0.0,
        retry_policy: Optional[RetryPolicy] = None,
        health: Optional[HealthConfig] = None,
        lease_seconds: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        if n_nodes < 1:
            raise ValueError("a federation needs at least one node")
        #: substrate-level traffic (node-local traffic aggregates on each
        #: node's own stats); lease_fences/epoch_stalls land here too
        self.stats = TrafficStats()
        self.retry_policy = retry_policy or RetryPolicy()
        self.clock = clock
        self.version_manager = VersionManager()
        self.provider_manager = ProviderManager(
            replication=page_replication, stats=self.stats, health=health
        )
        for i in range(n_data_providers):
            self.provider_manager.register(
                DataProvider(i, page_service_seconds)
            )
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="fed-dht"
        )
        self.metadata = MetadataDHT(
            n_metadata_providers,
            replication=metadata_replication,
            stats=self.stats,
            executor=self._pool,
            rpc_latency_seconds=metadata_latency_seconds,
            retry_policy=self.retry_policy,
            health=health,
        )
        self.coordinator = GcEpochCoordinator(
            lease_seconds=lease_seconds, clock=clock, health=health
        )
        #: serializes federated GC passes; held across node acks, lease
        #: waits and the home sweep by design (level 0, allow_blocking)
        self._gc_lock = make_lock("Federation._gc_lock")
        #: near-expiry threshold below which the lease guard renews inline
        self._renew_margin = lease_seconds * 0.5
        self._node_modes: List[str] = []
        self._fenced: List[bool] = []
        self._fence_locks: List = []
        self.nodes: List[Cluster] = []
        for i in range(n_nodes):
            node = Cluster(
                max_workers=max_workers,
                shared_cache_bytes=shared_cache_bytes,
                hot_replicas=False,
                page_service_seconds=page_service_seconds,
                retry_policy=self.retry_policy,
                health=health,
                version_manager=self.version_manager,
                provider_manager=self.provider_manager,
                metadata=self.metadata,
            )
            self._wire_node(i, node)
            self.nodes.append(node)
        home = self.nodes[0]
        #: the ONE repair service wired to the shared substrate's death
        #: verdicts (it happens to live on the home node)
        self.repair_service = home.repair_service
        self.provider_manager.on_dead = self.repair_service.schedule
        self.metadata.on_dead = self.repair_service.schedule
        self._closed = False

    def _wire_node(self, i: int, node: Cluster) -> None:
        self._node_modes.append(NODE_UP)
        self._fenced.append(False)
        self._fence_locks.append(make_lock("Federation._fence_lock"))
        node._federation = self
        node._node_id = i
        node._pin_sink = (
            lambda blob_id, version, i=i: self._pin_from_node(
                i, blob_id, version
            )
        )
        node._unpin_sink = (
            lambda blob_id, version, i=i: self._unpin_from_node(
                i, blob_id, version
            )
        )
        node._node_gate = lambda i=i: self._check_node(i)
        node._lease_guard = lambda i=i: self._lease_guard_check(i)
        self.coordinator.join(i)

    # -- topology --------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def node(self, i: int) -> Cluster:
        return self.nodes[i]

    def node_mode(self, i: int) -> str:
        return self._node_modes[i]

    def node_fenced(self, i: int) -> bool:
        return self._fenced[i]

    # -- per-op gates (installed on every node) --------------------------------
    def _check_node(self, i: int) -> None:
        mode = self._node_modes[i]
        if mode in (NODE_KILLED, NODE_WEDGED):
            raise ProviderFailed(f"node {i} is {mode}")

    def _coordinator_reachable(self, i: int) -> bool:
        return self._node_modes[i] == NODE_UP

    def _pin_from_node(self, i: int, blob_id: int, version: int) -> None:
        if not self._coordinator_reachable(i):
            raise ProviderFailed(
                f"node {i} cannot reach the GC coordinator "
                f"({self._node_modes[i]}); pin refused"
            )
        self.coordinator.pin(i, blob_id, version)

    def _unpin_from_node(self, i: int, blob_id: int, version: int) -> None:
        if not self._coordinator_reachable(i):
            raise ProviderFailed(
                f"node {i} cannot reach the GC coordinator "
                f"({self._node_modes[i]})"
            )
        self.coordinator.unpin(i, blob_id, version)

    def _lease_guard_check(self, i: int) -> bool:
        """The per-read gate: may node ``i``'s cache tiers serve right now?

        Valid lease → serve (renewing inline when near expiry and the
        coordinator is reachable). A renewal that fails because the epoch
        advanced (renew-under-GC) fences and rejoins — the implicit ack.
        Lapsed lease → fence BEFORE any further cache serve; rejoin
        immediately when the coordinator is reachable (the freshly purged
        tiers hold nothing stale), else stay fenced and read through."""
        coord = self.coordinator
        if coord.lease_valid(i):
            if (
                self._node_modes[i] == NODE_UP
                and coord.seconds_until_expiry(i) <= self._renew_margin
            ):
                if not coord.renew(i):
                    self._fence(i)
                    return self._rejoin(i)
            return True
        self._fence(i)
        if self._node_modes[i] != NODE_UP:
            return False
        return self._rejoin(i)

    def _fence(self, i: int) -> None:
        """Purge node ``i``'s tiers exactly once per fence transition."""
        with self._fence_locks[i]:
            if self._fenced[i]:
                return
            self._fenced[i] = True
            node = self.nodes[i]
            node.fence_caches()
            node.stats.record_lease_fence()
            self.stats.record_lease_fence()

    def _rejoin(self, i: int) -> bool:
        with self._fence_locks[i]:
            if self._node_modes[i] != NODE_UP:
                return False
            try:
                self.coordinator.join(i)
            except ProviderFailed:
                return False  # declared dead: only rejoin_node() revives
            self._fenced[i] = False
            return True

    # -- federated GC ----------------------------------------------------------
    def gc(
        self, blob_id: int, keep_versions: Sequence[int]
    ) -> Tuple[int, int]:
        """The epoch/lease GC protocol; called via any node's
        ``Cluster.gc`` (which delegates here) or directly.

        1. advance the epoch;
        2. per live node, obtain an **ack** (purge its tiers of the doomed
           versions, rejoin it at the new epoch), retrying per
           :class:`RetryPolicy` — every failed attempt feeds the node
           health machine, and a death verdict runs the death path
           (lease+pin reclaim, writer recovery) instead;
        3. a node that is unreachable but not dead is **waited out**: its
           lease expiry bounds the stall (``epoch_stalls``), and expiry
           guarantees the node fences before its next cache serve;
        4. sweep storage on the home node (whose local GC re-reads the
           coordinator pin set inside its gc guard, blocking new pins for
           the sweep's duration).

        Like single-node GC, the caller promises no concurrent accesses
        target the dropped versions."""
        home = self.nodes[0]
        with self._gc_lock:
            epoch = self.coordinator.advance_epoch()
            latest = self.version_manager.latest_published(blob_id)
            keep_cached = (
                set(keep_versions)
                | self.coordinator.pinned_versions(blob_id)
                | {ZERO_VERSION}
            )
            for i in range(len(self.nodes)):
                if self.coordinator.node_dead(i):
                    continue  # lease and pins were reclaimed with the verdict
                if self._ack_with_retries(i, blob_id, keep_cached, latest, epoch):
                    continue
                self._wait_out_lease(i, epoch)
            return home.gc(blob_id, keep_versions, _local=True)

    def _ack_with_retries(
        self,
        i: int,
        blob_id: int,
        keep_cached: Set[int],
        latest: int,
        epoch: int,
    ) -> bool:
        """True when node ``i`` is handled — acked (directly or by its own
        fence+rejoin) or declared dead (death path run)."""
        policy = self.retry_policy
        attempts = max(policy.max_attempts, 1)
        for attempt in range(attempts):
            if self.coordinator.joined_epoch(i) == epoch:
                self.coordinator.note_success(i)
                return True  # implicit ack: the node fenced+rejoined itself
            try:
                self._ack_node(i, blob_id, keep_cached, latest)
                return True
            except ProviderFailed:
                if self.coordinator.note_failure(i):
                    self._handle_node_death(i)
                    return True
                if attempt + 1 < attempts:
                    self.stats.record_retry()
                    policy.backoff(attempt)
        return False

    def _ack_node(
        self, i: int, blob_id: int, keep_cached: Set[int], latest: int
    ) -> None:
        """One ack RPC: purge the node's tiers of the doomed versions and
        rejoin it at the current epoch. Raises ``ProviderFailed`` when the
        node is unreachable (killed / wedged / partitioned)."""
        if self._node_modes[i] != NODE_UP:
            raise ProviderFailed(f"node {i} is {self._node_modes[i]}")
        node = self.nodes[i]
        caches = [node.shared_cache] + [s.cache for s in node.sessions()]
        for cache in caches:
            if cache is not None:
                cache.drop_versions(blob_id, keep_cached, max_version=latest)
        self.coordinator.note_success(i)
        self.coordinator.join(i)
        with self._fence_locks[i]:
            self._fenced[i] = False

    def _wait_out_lease(self, i: int, epoch: int) -> None:
        """An unreachable-but-not-dead node stalls the pass until its lease
        expires (or it acks by rejoining on its own): past expiry the node
        cannot serve a cached page without fencing first, so reclaim is
        safe without its ack."""
        coord = self.coordinator
        stalled = False
        while True:
            if coord.joined_epoch(i) == epoch:
                return  # implicit ack
            remaining = coord.seconds_until_expiry(i)
            if remaining <= 0.0:
                return  # lease lapsed: the node fences before its next serve
            if not stalled:
                stalled = True
                self.stats.record_epoch_stall()
            # sleep on the policy's injectable sleep so chaos tests drive
            # this loop with a fake clock, bounded so a lease granted on a
            # coarse clock still converges quickly
            self.retry_policy.sleep(
                min(remaining, max(self.coordinator.lease_seconds * 0.1, 1e-4))
            )

    def _handle_node_death(self, i: int) -> None:
        """Death path: reclaim the lease and pins, fence whatever the node
        cached, and abandon its sessions' in-flight writes so in-order
        publication never wedges behind the dead writers."""
        node = self.nodes[i]
        self.coordinator.reclaim(i)
        with self._fence_locks[i]:
            self._fenced[i] = True
        self.repair_service.recover_writers(node.sessions())

    # -- node-plane faults (chaos harness) -------------------------------------
    def apply_node_fault(self, i: int, action: str) -> None:
        """The chaos harness's node plane: ``kill`` / ``wedge`` drop the
        whole node (every data op raises), ``partition`` cuts only the
        coordinator RPCs (the data plane still works — the fencing story),
        ``recover`` rejoins the node at the current epoch."""
        if action == "kill":
            self._node_modes[i] = NODE_KILLED
        elif action == "wedge":
            self._node_modes[i] = NODE_WEDGED
        elif action == "partition":
            self._node_modes[i] = NODE_PARTITIONED
        elif action == "recover":
            self.rejoin_node(i)
        else:
            raise ValueError(f"unknown node fault action {action!r}")

    def rejoin_node(self, i: int) -> None:
        """Bring a downed node back: purge its tiers (it may have missed any
        number of GC purges while away), clear its health record, resync its
        pins, grant a fresh lease at the current epoch.

        The pin resync reconciles both drift directions a downtime window
        accrues: unpins the node issued while unreachable were swallowed
        best-effort (the coordinator would otherwise protect the released
        versions forever), and a death verdict reclaimed pins the node's
        live snapshots still hold. The mode flips to ``up`` only after the
        resync, so no new pin can interleave with the snapshot."""
        self.coordinator.revive(i)
        with self._fence_locks[i]:
            self.nodes[i].fence_caches()
            self.coordinator.join(i)
            self.coordinator.sync_pins(i, self.nodes[i].local_pins())
            self._fenced[i] = False
        self._node_modes[i] = NODE_UP

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for node in self.nodes:
            node.close()
        self.metadata.close()
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "Federation":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
