"""Metadata-provider DHT abstraction (paper §III.A, "metadata provider").

The paper stores segment-tree nodes in BambooDHT across *metadata providers*.
Here the DHT is a set of in-process shards keyed by a stable hash of the node
key. Nodes are immutable and **create-only** (never mutated, never overwritten
with different content), so gets and puts need no locking beyond the
interpreter's atomic dict operations — this mirrors the lock-free property of
the paper's design rather than merely simulating it.

A :class:`TrafficStats` recorder counts RPCs and bytes, with and without the
paper's client-side RPC aggregation (§V.A: "delays RPC calls to a single
machine and streams all of them in a single real RPC call"), so benchmarks can
model network completion time for the Fig. 3 reproductions.
"""

from __future__ import annotations

import dataclasses
import itertools
import random
import threading
import time
from collections import defaultdict
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple, TypeVar

import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.segment_tree import NodeKey, TreeNode

_T = TypeVar("_T")
_R = TypeVar("_R")


class ProviderFailed(RuntimeError):
    """Raised when an injected failure makes a provider unreachable."""


#: provider/shard health states (paper-deferred fault tolerance, PR 7; the
#: metadata plane joined in PR 8). ``live`` nodes take fresh traffic;
#: ``suspect`` ones (recent RPC failures within the decay window) still serve
#: but are candidates for retry avoidance; ``dead`` ones (failure count over
#: threshold) are excluded and trigger re-replication repair.
LIVE = "live"
SUSPECT = "suspect"
DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Failure-detection knobs shared by the data plane's
    :class:`~repro.core.provider.ProviderManager` and the metadata plane's
    :class:`MetadataDHT`.

    A node becomes ``suspect`` after ``suspect_after`` observed RPC
    failures inside the trailing ``window_seconds``, and ``dead`` at
    ``dead_after`` failures. Suspicion decays: once the window slides past
    the recorded failures the node is ``live`` again. Death is sticky —
    only an explicit recover call (the rejoin announcement) or an observed
    success clears it. ``clock`` is injectable so tests drive the decay
    window deterministically.
    """

    suspect_after: int = 1
    dead_after: int = 3
    window_seconds: float = 30.0
    clock: Callable[[], float] = time.monotonic


#: monotonically numbers RetryPolicy instances (see ``RetryPolicy.nonce``)
_POLICY_NONCES = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter, shared by the
    data plane's page RPCs and the metadata plane's shard RPCs.

    ``delay(attempt)`` grows ``base_delay_seconds`` by ``multiplier`` per
    attempt, capped at ``max_delay_seconds``, then adds up to ``jitter``
    fraction of deterministic noise. The noise stream is seeded by
    ``(seed, nonce, attempt)`` where ``nonce`` defaults to a fresh
    per-instance value: one policy instance replays its exact schedule
    (``sleep`` is injectable so tests record it without wall-clock cost),
    but N policies constructed with the same ``seed`` — one per session or
    per node, the common construction — get *distinct* jitter streams.
    Without the nonce, same-seed policies backed off in lockstep and their
    synchronized retry waves re-stampeded whichever provider or shard had
    just recovered. Pass an explicit ``nonce`` to replay a specific stream
    across instances.
    """

    max_attempts: int = 3
    base_delay_seconds: float = 0.005
    multiplier: float = 2.0
    max_delay_seconds: float = 0.1
    jitter: float = 0.5
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep
    nonce: int = dataclasses.field(
        default_factory=lambda: next(_POLICY_NONCES)
    )

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (0-based)."""
        raw = min(
            self.base_delay_seconds * (self.multiplier ** attempt),
            self.max_delay_seconds,
        )
        rng = random.Random(
            (self.seed * 0x9E3779B1)
            ^ (self.nonce * 0x85EBCA6B)
            ^ (attempt * 0xC2B2AE3D)
        )
        return raw * (1.0 + self.jitter * rng.random())

    def backoff(self, attempt: int) -> None:
        self.sleep(self.delay(attempt))

    def max_backoff_seconds(self) -> float:
        """Worst-case total injected sleep for one fully retried RPC — the
        bound chaos tests assert a dead shard can never exceed."""
        return sum(
            self.delay(attempt) for attempt in range(max(self.max_attempts - 1, 0))
        )


#: per-word-count weight vectors for :func:`page_checksum`, cached per page
#: size (all pages of one blob share a size, so this holds a handful of
#: entries). Concurrent first-computes race benignly: both produce the same
#: vector.
_CHECKSUM_WEIGHTS: Dict[int, "np.ndarray"] = {}


def page_checksum(page) -> int:
    """End-to-end integrity checksum of one stored page: a position-weighted
    64-bit word sum (Fletcher-style, vectorized). The verify runs on EVERY
    provider fetch, so this sits on the read hot path — the numpy reduction
    is ~6x faster than ``zlib.crc32`` on a 64 KiB page. Same threat model as
    a CRC: detects random corruption (any single corrupted word is caught
    outright — every weight is odd, hence invertible mod 2**64 — and
    multi-word damage survives with probability ~2**-64), not adversarial
    tampering. Computed once at ``writev`` freeze time (the page is
    immutable from that point on), stored in the leaf's
    :class:`TreeNode`, and verified on every provider fetch — a mismatch is
    treated exactly like a provider failure: replica fallback plus repair
    of the corrupt copy."""
    data = np.frombuffer(memoryview(page).cast("B"), dtype=np.uint8)
    tail = data.size % 8
    if tail:  # pad the rare non-word-aligned page to a whole word count
        data = np.concatenate([data, np.zeros(8 - tail, np.uint8)])
    words = data.view(np.uint64)
    weights = _CHECKSUM_WEIGHTS.get(words.size)
    if weights is None:
        weights = (
            np.arange(words.size, dtype=np.uint64)
            * np.uint64(0x9E3779B97F4A7C15)  # golden-ratio odd multiplier
            | np.uint64(1)
        )
        _CHECKSUM_WEIGHTS[words.size] = weights
    plain = int(np.add.reduce(words))
    weighted = int(np.add.reduce(words * weights))
    return (plain ^ (weighted << 1)) & 0xFFFFFFFFFFFFFFFF


@dataclasses.dataclass
class TrafficStats:
    """Thread-safe accounting of logical RPCs / bytes per destination.

    ``rpcs`` counts logical messages, ``aggregated_rpcs`` counts the real
    wire round-trips after the paper's client-side aggregation (§V.A) —
    broken down into ``data_rounds`` (data providers) and ``metadata_rounds``
    (metadata DHT shards). ``cache_hits``/``cache_misses`` track the client
    page cache, whose hits issue no RPC at all.
    """

    rpcs: int = 0
    aggregated_rpcs: int = 0
    bytes_sent: int = 0
    data_rounds: int = 0
    metadata_rounds: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    #: self-healing plane (PR 7): RPC attempts retried after a failure,
    #: per-page fetches served by a non-chosen replica after the chosen
    #: source failed, read ops that completed with at least one provider
    #: down, and pages re-replicated by the repair service
    retries: int = 0
    replica_fallbacks: int = 0
    degraded_reads: int = 0
    repaired_pages: int = 0
    #: metadata-plane self-healing (PR 8): shard RPC attempts re-issued after
    #: a failure, and stored pages whose checksum did not match on fetch
    #: (each one also triggers the replica-fallback + repair path)
    metadata_retries: int = 0
    checksum_failures: int = 0
    #: federated GC (PR 10): times a node fenced its cache tiers because its
    #: GC-epoch lease lapsed, and GC passes that had to stall waiting out an
    #: unresponsive node's lease before reclaiming storage
    lease_fences: int = 0
    epoch_stalls: int = 0
    per_dest_bytes: Dict[int, int] = dataclasses.field(default_factory=lambda: defaultdict(int))
    #: read-path bytes per DATA provider only (no metadata shards, no writes) —
    #: the skew signal the replica balancer promotes hot pages from
    per_dest_read_bytes: Dict[int, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    #: write-path bytes per DATA provider only — the placement-skew signal
    #: (hot-spotted writes) for the balancer and the write benchmarks
    per_dest_write_bytes: Dict[int, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    _lock: threading.Lock = dataclasses.field(
        default_factory=lambda: make_lock("TrafficStats._lock"), repr=False
    )

    def record(self, dest: int, n_messages: int, n_bytes: int) -> None:
        with self._lock:
            self._record_locked(dest, n_messages, n_bytes)

    def _record_locked(self, dest: int, n_messages: int, n_bytes: int) -> None:
        self.rpcs += n_messages
        self.aggregated_rpcs += 1
        self.bytes_sent += n_bytes
        self.per_dest_bytes[dest] += n_bytes

    def record_data(self, dest: int, n_messages: int, n_bytes: int, read: bool = False) -> None:
        """One aggregated round-trip to a data provider."""
        with self._lock:
            self._record_locked(dest, n_messages, n_bytes)
            self.data_rounds += 1
            if read:
                self.per_dest_read_bytes[dest] += n_bytes
            else:
                self.per_dest_write_bytes[dest] += n_bytes

    def read_bytes_snapshot(self) -> Dict[int, int]:
        """Copy of per-data-provider read bytes (for replica choice/skew)."""
        with self._lock:
            return dict(self.per_dest_read_bytes)

    def write_bytes_snapshot(self) -> Dict[int, int]:
        """Copy of per-data-provider write bytes (for write hot-spot skew)."""
        with self._lock:
            return dict(self.per_dest_write_bytes)

    def record_metadata(self, dest: int, n_messages: int, n_bytes: int) -> None:
        """One aggregated round-trip to a metadata shard."""
        with self._lock:
            self._record_locked(dest, n_messages, n_bytes)
            self.metadata_rounds += 1

    def record_cache(self, hits: int, misses: int) -> None:
        with self._lock:
            self.cache_hits += hits
            self.cache_misses += misses

    def record_retry(self, n: int = 1) -> None:
        """RPC attempts re-issued after a ``ProviderFailed``."""
        with self._lock:
            self.retries += n

    def record_fallback(self, n: int = 1) -> None:
        """Page fetches recovered via a replica after the source failed."""
        with self._lock:
            self.replica_fallbacks += n

    def record_degraded_read(self, n: int = 1) -> None:
        """Read ops completed while at least one provider was down."""
        with self._lock:
            self.degraded_reads += n

    def record_repair(self, n_pages: int) -> None:
        """Pages re-replicated by the repair service."""
        with self._lock:
            self.repaired_pages += n_pages

    def record_metadata_retry(self, n: int = 1) -> None:
        """Metadata shard RPC attempts re-issued after a failure."""
        with self._lock:
            self.metadata_retries += n

    def record_checksum_failure(self, n: int = 1) -> None:
        """Fetched pages whose stored checksum did not match their bytes."""
        with self._lock:
            self.checksum_failures += n

    def record_lease_fence(self, n: int = 1) -> None:
        """A node fenced its cache tiers after its GC-epoch lease lapsed."""
        with self._lock:
            self.lease_fences += n

    def record_epoch_stall(self, n: int = 1) -> None:
        """A federated GC pass waited out an unreachable node's lease."""
        with self._lock:
            self.epoch_stalls += n

    def reset(self) -> None:
        with self._lock:
            self.rpcs = 0
            self.aggregated_rpcs = 0
            self.bytes_sent = 0
            self.data_rounds = 0
            self.metadata_rounds = 0
            self.cache_hits = 0
            self.cache_misses = 0
            self.retries = 0
            self.replica_fallbacks = 0
            self.degraded_reads = 0
            self.repaired_pages = 0
            self.metadata_retries = 0
            self.checksum_failures = 0
            self.lease_fences = 0
            self.epoch_stalls = 0
            self.per_dest_bytes.clear()
            self.per_dest_read_bytes.clear()
            self.per_dest_write_bytes.clear()


#: Serialized size of one tree node on the wire; matches the order of
#: magnitude of the paper's implementation (key + two child versions + page
#: ref + framing).
NODE_WIRE_BYTES = 64


class MetadataShard:
    """One metadata provider: an in-memory, create-only node store."""

    def __init__(self, shard_id: int) -> None:
        self.shard_id = shard_id
        self._nodes: Dict[NodeKey, TreeNode] = {}
        self.failed = False
        #: chaos-harness hook (:mod:`repro.core.faults`): called at RPC entry
        #: with ``(op, shard_id)``, mirroring ``DataProvider.fault_gate`` —
        #: an injector may sleep (delay), raise ``ProviderFailed`` (drop), or
        #: flip failure flags; shards hold no lock, so the gate runs free
        self.fault_gate: Optional[Callable[[str, int], None]] = None

    def _gate(self, op: str) -> None:
        gate = self.fault_gate
        if gate is not None:
            gate(op, self.shard_id)

    def put_many(self, nodes: Sequence[TreeNode]) -> None:
        self._gate("put_many")
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        for node in nodes:
            # Create-only: concurrent writers never target the same key
            # because keys embed the (unique) version number. The sanctioned
            # re-puts are leaf rewrites that keep the page DATA identical and
            # change only placement hints: the replica balancer's
            # grown/shrunk replica sets and the repair service's
            # re-replication (both serialize on the rebalance lock), plus a
            # writer correcting its OWN still-unpublished leaves after a
            # mid-flight provider death (no one else targets those keys
            # until the version publishes).
            self._nodes[node.key] = node

    def get(self, key: NodeKey) -> Optional[TreeNode]:
        self._gate("get")
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        return self._nodes.get(key)

    def get_many(self, keys: Sequence[NodeKey]) -> Dict[NodeKey, TreeNode]:
        """One aggregated RPC: every found node for ``keys`` (missing keys are
        simply absent from the result — the caller decides whether to fall
        back to a replica or error)."""
        self._gate("get_many")
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        out: Dict[NodeKey, TreeNode] = {}
        for key in keys:
            node = self._nodes.get(key)
            if node is not None:
                out[key] = node
        return out

    def nodes_of_blob(self, blob_id: int) -> Dict[NodeKey, TreeNode]:
        self._gate("nodes_of_blob")
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        return {k: n for k, n in list(self._nodes.items()) if k.blob_id == blob_id}

    def delete_many(self, keys: Iterable[NodeKey]) -> None:
        for key in keys:
            self._nodes.pop(key, None)

    def __len__(self) -> int:
        return len(self._nodes)


class MetadataDHT:
    """Hash-dispersed node store over ``n_shards`` metadata providers.

    ``replication`` > 1 stores each node on that many consecutive shards
    (BambooDHT-style neighbor replication); reads fall back across replicas,
    which is the paper's (inherited) metadata fault tolerance. Writes commit
    to a quorum of ``ceil(replication / 2)`` replicas per node — nodes are
    create-only and immutable, so a sub-majority quorum is sound: any single
    surviving copy is the truth, reads fall back across all ``replication``
    homes, and :meth:`restore_replication` (driven by the repair service)
    rebuilds lost copies from survivors. Every shard RPC runs under the
    shared bounded :class:`RetryPolicy` and the same ``live → suspect →
    dead`` health machine the data plane uses: observed failures accumulate
    toward a death verdict (``on_dead`` schedules repair), a declared-dead
    shard fails fast instead of burning the retry budget, and an optional
    ``rpc_timeout_seconds`` bounds each attempt so a wedged (delayed) shard
    degrades latency instead of hanging the read plane.

    ``rpc_latency_seconds`` > 0 models the wire round-trip of one *parallel
    round* of aggregated shard RPCs (the metadata half of the paper's network
    model — what the overlapped write plane hides behind the data puts): the
    concurrent per-shard RPCs of a round complete together one RTT after they
    are issued, so a round costs ONE flat sleep, not one per shard. The sleep
    holds no lock and occupies at most one pool worker, so the model adds
    latency without stealing execution resources from the real data plane.
    """

    def __init__(
        self,
        n_shards: int,
        replication: int = 1,
        stats: Optional[TrafficStats] = None,
        executor: Optional[ThreadPoolExecutor] = None,
        rpc_latency_seconds: float = 0.0,
        retry_policy: Optional[RetryPolicy] = None,
        health: Optional[HealthConfig] = None,
        rpc_timeout_seconds: Optional[float] = None,
    ) -> None:
        if replication > n_shards:
            raise ValueError("replication cannot exceed shard count")
        self.shards = [MetadataShard(i) for i in range(n_shards)]
        self.rpc_latency_seconds = rpc_latency_seconds
        self.replication = replication
        #: replicas a node put must land on for the write to succeed; see the
        #: class docstring for why ceil(R/2) (not majority-of-ack R) is sound
        #: for a create-only store
        self.write_quorum = (replication + 1) // 2
        self.stats = stats or TrafficStats()
        self.retry_policy = retry_policy or RetryPolicy()
        self.health_config = health or HealthConfig()
        #: per-attempt RPC bound; ``None`` (default) trusts shards to answer.
        #: When set, each attempt runs on a pool worker and is abandoned
        #: after the timeout (counted as a failure toward the shard's health)
        self.rpc_timeout_seconds = rpc_timeout_seconds
        #: shard health records, same shape as ``ProviderManager``'s: failure
        #: timestamps within the decay window plus the sticky dead set
        self._health_lock = make_lock("MetadataDHT._health_lock")
        self._failures: Dict[int, List[float]] = {}
        self._dead: set = set()
        #: invoked OUTSIDE the health lock when a shard transitions to dead —
        #: the cluster wires this to RepairService scheduling (metadata pass)
        self.on_dead: Optional[Callable[[int], None]] = None
        self._executor = executor
        self._owns_executor = False
        self._executor_lock = make_lock("MetadataDHT._executor_lock")
        # group-commit state for put_nodes_coalesced: writes arriving while
        # coalesce_max_rounds rounds are already in flight pile up here and
        # ride the next round together. The bound matters both ways: with
        # unbounded rounds nothing ever coalesces (that is put_nodes_async),
        # and with ONE serialized round a lone streamer pays +0.5 RTT per
        # write for no benefit — concurrent wire RPCs genuinely overlap
        self._coalesce_lock = make_lock("MetadataDHT._coalesce_lock")
        self._coalesce_pending: List[Tuple[List[TreeNode], Future]] = []
        self._coalesce_active = 0
        self.coalesce_max_rounds = 4
        #: rounds actually flushed by the coalescer (tests assert that N
        #: concurrent small writes cost fewer than N rounds)
        self.coalesced_rounds = 0

    def _round_trip(self) -> None:
        """One modeled RTT for a parallel round of shard RPCs."""
        if self.rpc_latency_seconds > 0.0:
            time.sleep(self.rpc_latency_seconds)

    def _pool(self) -> ThreadPoolExecutor:
        with self._executor_lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=min(len(self.shards), 16)
                )
                self._owns_executor = True
            return self._executor

    def _fan_out(
        self, batches: List[Tuple[int, List[_T]]], fn: Callable[[int, List[_T]], _R]
    ) -> List[_R]:
        """Run ``fn(shard_id, batch)`` for every per-shard batch concurrently —
        one traversal level (or one writev's node set) costs ONE parallel
        round over the shards instead of a serial Python loop (paper §III.B
        "parallel per level"). A single batch skips the pool entirely."""
        if len(batches) <= 1:
            return [fn(sid, batch) for sid, batch in batches]
        futures = [self._pool().submit(fn, sid, batch) for sid, batch in batches]
        return [f.result() for f in futures]

    def close(self) -> None:
        # detach under the lock, shut down OUTSIDE it: shutdown(wait=True)
        # joins pool workers, and a worker calling _pool() while close()
        # blocks on it inside _executor_lock would deadlock
        pool: Optional[ThreadPoolExecutor] = None
        with self._executor_lock:
            if self._owns_executor and self._executor is not None:
                pool = self._executor
                self._executor = None
                self._owns_executor = False
        if pool is not None:
            pool.shutdown(wait=True)

    # -- shard health (live -> suspect -> dead, mirroring ProviderManager) ---
    def note_shard_failure(self, shard_id: int) -> None:
        """Record an observed shard RPC failure; transitions the shard
        ``live -> suspect -> dead`` per :class:`HealthConfig`. ``on_dead``
        fires exactly once per death, outside the health lock (it schedules
        repair work that takes other locks)."""
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        newly_dead = False
        with self._health_lock:
            record = self._failures.setdefault(shard_id, [])
            record.append(now)
            while record and record[0] < horizon:
                record.pop(0)
            if (
                len(record) >= self.health_config.dead_after
                and shard_id not in self._dead
            ):
                self._dead.add(shard_id)
                newly_dead = True
            callback = self.on_dead
        if newly_dead and callback is not None:
            callback(shard_id)

    def note_shard_success(self, shard_id: int) -> None:
        """An observed successful RPC clears suspicion and death (recovery is
        observed, not configured — same rule as the data plane). The unlocked
        membership probe keeps the healthy fast path free; the race with a
        concurrent ``note_shard_failure`` is a benign interleaving of the two
        observations."""
        if shard_id not in self._failures and shard_id not in self._dead:
            return
        with self._health_lock:
            self._failures.pop(shard_id, None)
            self._dead.discard(shard_id)

    def shard_health(self, shard_id: int) -> str:
        """``live``/``suspect``/``dead`` verdict for one shard."""
        now = self.health_config.clock()
        horizon = now - self.health_config.window_seconds
        with self._health_lock:
            if shard_id in self._dead:
                return DEAD
            record = self._failures.get(shard_id)
            if not record:
                return LIVE
            recent = sum(1 for t in record if t >= horizon)
            return SUSPECT if recent >= self.health_config.suspect_after else LIVE

    def dead_shards(self) -> List[int]:
        """Shard ids currently declared dead (the repair pass's work queue)."""
        with self._health_lock:
            return sorted(self._dead)

    # -- bounded shard RPC (retry + per-attempt timeout) ---------------------
    def _attempt(self, sid: int, fn: Callable[[], _R], timed: bool) -> _R:
        """One shard RPC attempt, bounded by ``rpc_timeout_seconds`` when set
        (and ``timed``): the call runs on a pool worker and is abandoned on
        timeout, which surfaces as a ``ProviderFailed`` — a wedged shard
        costs one timeout per attempt, never a hang. ``timed=False`` callers
        (the async write rounds, which already run ON a pool worker) stay
        inline so a saturated pool cannot deadlock on nested futures."""
        timeout = self.rpc_timeout_seconds
        if timeout is None or not timed:
            return fn()
        fut = self._pool().submit(fn)
        try:
            return fut.result(timeout=timeout)
        except FutureTimeout:
            raise ProviderFailed(
                f"metadata shard {sid} RPC timed out after {timeout}s"
            ) from None

    def _with_retry(self, sid: int, fn: Callable[[], _R], timed: bool = True) -> _R:
        """Run one shard RPC under the bounded :class:`RetryPolicy`. Every
        failed attempt is recorded against the shard's health; retries stop
        early once the shard is declared dead (fail fast — its replicas
        carry the load) and never run under a lock."""
        policy = self.retry_policy
        attempts = max(policy.max_attempts, 1)
        for attempt in range(attempts):
            try:
                out = self._attempt(sid, fn, timed)
            except ProviderFailed:
                self.note_shard_failure(sid)
                if attempt + 1 < attempts and sid not in self.dead_shards():
                    self.stats.record_metadata_retry()
                    policy.backoff(attempt)
                    continue
                raise
            self.note_shard_success(sid)
            return out
        raise AssertionError("unreachable")  # pragma: no cover

    def _check_quorum(self, nodes: Sequence[TreeNode], failed: set) -> None:
        """Raise unless every node landed on at least ``write_quorum`` of its
        replica shards (``failed`` holds the shard ids whose batch store
        failed after retries)."""
        if not failed:
            return
        for node in nodes:
            stored = sum(
                1 for sid in self._replica_ids(node.key) if sid not in failed
            )
            if stored < self.write_quorum:
                raise ProviderFailed(
                    f"metadata write quorum lost for {node.key}: {stored}/"
                    f"{self.replication} replicas stored "
                    f"(need {self.write_quorum})"
                )

    def _home(self, key: NodeKey) -> int:
        return hash((key.blob_id, key.version, key.offset, key.size)) % len(self.shards)

    def _replica_ids(self, key: NodeKey) -> List[int]:
        home = self._home(key)
        return [(home + r) % len(self.shards) for r in range(self.replication)]

    def put_nodes(self, nodes: Sequence[TreeNode]) -> None:
        """Store nodes, aggregating all puts to the same shard into one RPC;
        the per-shard RPCs are issued concurrently (one parallel round), each
        under the retry policy. A shard that stays down after retries costs
        its replicas only: the put succeeds as long as every node reached its
        write quorum, and raises ``ProviderFailed`` otherwise."""
        by_shard: Dict[int, List[TreeNode]] = defaultdict(list)
        for node in nodes:
            for sid in self._replica_ids(node.key):
                by_shard[sid].append(node)

        def _put(sid: int, batch: List[TreeNode]) -> Optional[int]:
            try:
                self._with_retry(sid, lambda: self.shards[sid].put_many(batch))
            except ProviderFailed:
                return sid
            self.stats.record_metadata(sid, len(batch), len(batch) * NODE_WIRE_BYTES)
            return None

        failed = {
            sid
            for sid in self._fan_out(list(by_shard.items()), _put)
            if sid is not None
        }
        self._round_trip()
        self._check_quorum(nodes, failed)

    def put_nodes_async(self, nodes: Sequence[TreeNode]) -> List[Future]:
        """Pipelined :meth:`put_nodes`: returns immediately with the round's
        future(s); the overlapped write plane stores a writev's metadata
        while its data puts are still in flight, joining everything only
        before ``report_success``. The round runs on ONE pool worker that
        performs the per-shard batch stores back-to-back (in-process dict
        inserts, microseconds each — fanning them out would cost more in task
        dispatch, and a worker waiting on nested futures could deadlock a
        saturated pool) and then sleeps one modeled RTT for the whole round,
        mirroring what concurrent per-shard wire RPCs would cost."""
        by_shard: Dict[int, List[TreeNode]] = defaultdict(list)
        for node in nodes:
            for sid in self._replica_ids(node.key):
                by_shard[sid].append(node)
        frozen = list(nodes)

        def _put_round() -> None:
            failed = set()
            for sid, batch in by_shard.items():
                try:
                    # timed=False: this worker must not wait on a nested
                    # pool future (a saturated pool would deadlock)
                    self._with_retry(
                        sid, lambda: self.shards[sid].put_many(batch), timed=False
                    )
                except ProviderFailed:
                    failed.add(sid)
                    continue
                self.stats.record_metadata(sid, len(batch), len(batch) * NODE_WIRE_BYTES)
            self._round_trip()
            self._check_quorum(frozen, failed)

        return [self._pool().submit(_put_round)]

    def put_nodes_coalesced(self, nodes: Sequence[TreeNode]) -> List[Future]:
        """Group-commit metadata store: the cross-writev half of the paper's
        RPC aggregation. Up to ``coalesce_max_rounds`` rounds run
        concurrently (concurrent wire RPCs overlap their RTTs, exactly like
        ``put_nodes_async`` — a lightly loaded streamer keeps its latency);
        node batches from writes that arrive while all round slots are busy
        are merged into ONE per-shard batch round (one aggregated RPC per
        shard, one modeled RTT for all of them) instead of paying a shard
        round per write — the ``write_async`` window routes its writes
        through here, so a burst of small fine-grain writes shares metadata
        rounds the way one big ``writev`` always has. Returns one future
        that resolves when this call's nodes are durable; a shard failure
        fails exactly the calls that stored nodes on that shard, not the
        whole round."""
        fut: Future = Future()
        with self._coalesce_lock:
            self._coalesce_pending.append((list(nodes), fut))
            launch = self._coalesce_active < self.coalesce_max_rounds
            if launch:
                self._coalesce_active += 1
        if launch:
            try:
                self._pool().submit(self._coalesce_flush)
            except BaseException as err:
                # executor gone (shutdown race): return the slot and fail
                # whatever is queued if no live flusher remains to drain it —
                # a stranded future would hang its writer's join forever
                with self._coalesce_lock:
                    self._coalesce_active -= 1
                    stranded = []
                    if self._coalesce_active == 0:
                        stranded, self._coalesce_pending = (
                            self._coalesce_pending, []
                        )
                for _, pending_fut in stranded:
                    pending_fut.set_exception(err)
                raise
        return [fut]

    def _coalesce_flush(self) -> None:
        """Drain the coalesce queue: each loop iteration takes EVERYTHING
        queued so far as one round (per-shard aggregated stores + one RTT),
        then re-checks — writes that arrived while every round slot was busy
        ride the next loop. Runs on a pool worker per active round; the
        per-shard stores are in-process dict inserts (fanning them out would
        cost more in task dispatch than it saves, exactly like
        ``put_nodes_async``)."""
        while True:
            with self._coalesce_lock:
                batch, self._coalesce_pending = self._coalesce_pending, []
                if not batch:
                    self._coalesce_active -= 1
                    return
                self.coalesced_rounds += 1  # under the lock: flushes race
            by_shard: Dict[int, List[TreeNode]] = defaultdict(list)
            for nodes, _ in batch:
                for node in nodes:
                    for sid in self._replica_ids(node.key):
                        by_shard[sid].append(node)
            failed: set = set()
            for sid, shard_nodes in by_shard.items():
                try:
                    # timed=False: flush workers must not wait on nested
                    # pool futures (a saturated pool would deadlock)
                    self._with_retry(
                        sid,
                        lambda: self.shards[sid].put_many(shard_nodes),
                        timed=False,
                    )
                except BaseException:
                    failed.add(sid)
                    continue
                self.stats.record_metadata(
                    sid, len(shard_nodes), len(shard_nodes) * NODE_WIRE_BYTES
                )
            self._round_trip()
            # settle per queued write: a failed shard fails exactly the calls
            # whose nodes dropped below their write quorum, not the round
            for nodes, fut in batch:
                try:
                    self._check_quorum(nodes, failed)
                except ProviderFailed as err:
                    fut.set_exception(err)
                else:
                    fut.set_result(None)

    def get_node(self, key: NodeKey) -> TreeNode:
        last_err: Optional[Exception] = None
        for sid in self._replica_ids(key):
            try:
                node = self._with_retry(sid, lambda: self.shards[sid].get(key))
                self.stats.record_metadata(sid, 1, NODE_WIRE_BYTES)
                self._round_trip()
            except ProviderFailed as err:  # replica fallback
                last_err = err
                continue
            if node is not None:
                return node
        if last_err is not None:
            raise last_err
        raise KeyError(f"metadata node not found: {key}")

    def get_nodes(
        self,
        keys: Sequence[NodeKey],
        on_partial: Optional[Callable[[Dict[NodeKey, TreeNode]], None]] = None,
    ) -> Dict[NodeKey, TreeNode]:
        """Batched node fetch: ONE aggregated RPC per (home) shard for the
        whole key set — the per-shard RPCs of each round run concurrently —
        with per-key replica fallback rounds on shard failure or missing
        replicas. Raises ``KeyError`` if any key is nowhere.

        ``on_partial`` switches the round into *streaming* delivery (the
        read-plane pipeline): each shard batch's found nodes are handed to
        the callback the moment that shard's RPC completes — possibly
        concurrently from pool workers, and crucially *without waiting for
        the round's slower shards* — so the caller can launch data-page
        fetches while the rest of the traversal level is still in flight.
        The modeled RTT of a streaming round elapses BEFORE the per-shard
        results are delivered (a response can only be acted on one round
        trip after the round is issued), so streaming never under-counts
        latency; the complete result dict is still returned at the end."""
        found: Dict[NodeKey, TreeNode] = {}
        pending = list(dict.fromkeys(keys))
        last_err: Optional[ProviderFailed] = None

        def _get(
            sid: int, batch: List[NodeKey]
        ) -> Tuple[List[NodeKey], Optional[Dict[NodeKey, TreeNode]], Optional[ProviderFailed]]:
            try:
                got = self._with_retry(sid, lambda: self.shards[sid].get_many(batch))
                self.stats.record_metadata(sid, len(batch), len(batch) * NODE_WIRE_BYTES)
                if on_partial is not None and got:
                    on_partial(got)
                return batch, got, None
            except ProviderFailed as err:
                return batch, None, err

        for round_idx in range(self.replication):
            if not pending:
                break
            by_shard: Dict[int, List[NodeKey]] = defaultdict(list)
            # inline (home + round) % n rather than _replica_ids(...)[round_idx]:
            # this loop runs per key per traversal level on the read hot path,
            # and the per-key list allocation is measurable there
            home_of, n_shards = self._home, len(self.shards)
            for key in pending:
                by_shard[(home_of(key) + round_idx) % n_shards].append(key)
            if on_partial is not None:
                self._round_trip()  # streaming: deliver at response-arrival time
            still_missing: List[NodeKey] = []
            for batch, got, err in self._fan_out(list(by_shard.items()), _get):
                if err is not None:
                    last_err = err
                    still_missing.extend(batch)
                    continue
                assert got is not None
                found.update(got)
                still_missing.extend(k for k in batch if k not in got)
            if on_partial is None:
                self._round_trip()
            pending = still_missing
        if pending:
            if last_err is not None:  # an outage, not a lost node
                raise last_err
            raise KeyError(f"metadata nodes not found: {pending[:3]}" +
                           (f" (+{len(pending) - 3} more)" if len(pending) > 3 else ""))
        return found

    def iter_nodes(self, blob_id: int):
        """Iterate ``(key, node)`` over every stored node of ``blob_id``,
        deduplicated across replicas (public API for GC — callers must not
        reach into shard internals)."""
        merged: Dict[NodeKey, TreeNode] = {}
        for shard in self.shards:
            try:
                merged.update(
                    self._with_retry(
                        shard.shard_id, lambda s=shard: s.nodes_of_blob(blob_id)
                    )
                )
            except ProviderFailed:
                continue  # replicas on live shards still cover its nodes
        return iter(merged.items())

    def delete_nodes(self, keys: Iterable[NodeKey]) -> None:
        by_shard: Dict[int, List[NodeKey]] = defaultdict(list)
        for key in keys:
            for sid in self._replica_ids(key):
                by_shard[sid].append(key)
        for sid, batch in by_shard.items():
            self.shards[sid].delete_many(batch)

    def restore_replication(self, nodes: Sequence[TreeNode]) -> int:
        """Metadata re-replication (the repair service's metadata pass): for
        every given node, ensure a copy exists on each of its *live* replica
        shards, re-putting the copies a dead-then-recovered (or wiped)
        replica lost. Per live shard this costs one aggregated ``get_many``
        probe plus at most one ``put_many`` of the missing nodes; shards that
        are still down are skipped (the next pass gets them). Returns the
        number of node copies restored."""
        if self.replication <= 1:
            return 0
        wanted: Dict[int, Dict[NodeKey, TreeNode]] = defaultdict(dict)
        for node in nodes:
            for sid in self._replica_ids(node.key):
                wanted[sid][node.key] = node
        restored = 0
        for sid, want in wanted.items():
            keys = list(want)
            try:
                held = self._with_retry(
                    sid, lambda: self.shards[sid].get_many(keys)
                )
            except ProviderFailed:
                continue  # still down: repair again after it rejoins
            missing = [node for key, node in want.items() if key not in held]
            if not missing:
                continue
            try:
                self._with_retry(
                    sid, lambda: self.shards[sid].put_many(missing)
                )
            except ProviderFailed:
                continue
            self.stats.record_metadata(
                sid, len(missing), len(missing) * NODE_WIRE_BYTES
            )
            restored += len(missing)
        return restored

    def total_nodes(self) -> int:
        return sum(len(s) for s in self.shards)

    def fail_shard(self, shard_id: int) -> None:
        self.shards[shard_id].failed = True

    def recover_shard(self, shard_id: int) -> None:
        """Rejoin announcement: clear the failure flag AND the health record,
        so the shard comes back ``live`` immediately (matching
        ``ProviderManager.recover_provider``). Nodes stored while it was down
        are missing until :meth:`restore_replication` re-puts them."""
        self.shards[shard_id].failed = False
        with self._health_lock:
            self._failures.pop(shard_id, None)
            self._dead.discard(shard_id)
