"""Metadata-provider DHT abstraction (paper §III.A, "metadata provider").

The paper stores segment-tree nodes in BambooDHT across *metadata providers*.
Here the DHT is a set of in-process shards keyed by a stable hash of the node
key. Nodes are immutable and **create-only** (never mutated, never overwritten
with different content), so gets and puts need no locking beyond the
interpreter's atomic dict operations — this mirrors the lock-free property of
the paper's design rather than merely simulating it.

A :class:`TrafficStats` recorder counts RPCs and bytes, with and without the
paper's client-side RPC aggregation (§V.A: "delays RPC calls to a single
machine and streams all of them in a single real RPC call"), so benchmarks can
model network completion time for the Fig. 3 reproductions.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple, TypeVar

from repro.analysis.lockwatch import make_lock
from repro.core.segment_tree import NodeKey, TreeNode

_T = TypeVar("_T")
_R = TypeVar("_R")


class ProviderFailed(RuntimeError):
    """Raised when an injected failure makes a provider unreachable."""


@dataclasses.dataclass
class TrafficStats:
    """Thread-safe accounting of logical RPCs / bytes per destination.

    ``rpcs`` counts logical messages, ``aggregated_rpcs`` counts the real
    wire round-trips after the paper's client-side aggregation (§V.A) —
    broken down into ``data_rounds`` (data providers) and ``metadata_rounds``
    (metadata DHT shards). ``cache_hits``/``cache_misses`` track the client
    page cache, whose hits issue no RPC at all.
    """

    rpcs: int = 0
    aggregated_rpcs: int = 0
    bytes_sent: int = 0
    data_rounds: int = 0
    metadata_rounds: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    #: self-healing plane (PR 7): RPC attempts retried after a failure,
    #: per-page fetches served by a non-chosen replica after the chosen
    #: source failed, read ops that completed with at least one provider
    #: down, and pages re-replicated by the repair service
    retries: int = 0
    replica_fallbacks: int = 0
    degraded_reads: int = 0
    repaired_pages: int = 0
    per_dest_bytes: Dict[int, int] = dataclasses.field(default_factory=lambda: defaultdict(int))
    #: read-path bytes per DATA provider only (no metadata shards, no writes) —
    #: the skew signal the replica balancer promotes hot pages from
    per_dest_read_bytes: Dict[int, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    #: write-path bytes per DATA provider only — the placement-skew signal
    #: (hot-spotted writes) for the balancer and the write benchmarks
    per_dest_write_bytes: Dict[int, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    _lock: threading.Lock = dataclasses.field(
        default_factory=lambda: make_lock("TrafficStats._lock"), repr=False
    )

    def record(self, dest: int, n_messages: int, n_bytes: int) -> None:
        with self._lock:
            self._record_locked(dest, n_messages, n_bytes)

    def _record_locked(self, dest: int, n_messages: int, n_bytes: int) -> None:
        self.rpcs += n_messages
        self.aggregated_rpcs += 1
        self.bytes_sent += n_bytes
        self.per_dest_bytes[dest] += n_bytes

    def record_data(self, dest: int, n_messages: int, n_bytes: int, read: bool = False) -> None:
        """One aggregated round-trip to a data provider."""
        with self._lock:
            self._record_locked(dest, n_messages, n_bytes)
            self.data_rounds += 1
            if read:
                self.per_dest_read_bytes[dest] += n_bytes
            else:
                self.per_dest_write_bytes[dest] += n_bytes

    def read_bytes_snapshot(self) -> Dict[int, int]:
        """Copy of per-data-provider read bytes (for replica choice/skew)."""
        with self._lock:
            return dict(self.per_dest_read_bytes)

    def write_bytes_snapshot(self) -> Dict[int, int]:
        """Copy of per-data-provider write bytes (for write hot-spot skew)."""
        with self._lock:
            return dict(self.per_dest_write_bytes)

    def record_metadata(self, dest: int, n_messages: int, n_bytes: int) -> None:
        """One aggregated round-trip to a metadata shard."""
        with self._lock:
            self._record_locked(dest, n_messages, n_bytes)
            self.metadata_rounds += 1

    def record_cache(self, hits: int, misses: int) -> None:
        with self._lock:
            self.cache_hits += hits
            self.cache_misses += misses

    def record_retry(self, n: int = 1) -> None:
        """RPC attempts re-issued after a ``ProviderFailed``."""
        with self._lock:
            self.retries += n

    def record_fallback(self, n: int = 1) -> None:
        """Page fetches recovered via a replica after the source failed."""
        with self._lock:
            self.replica_fallbacks += n

    def record_degraded_read(self, n: int = 1) -> None:
        """Read ops completed while at least one provider was down."""
        with self._lock:
            self.degraded_reads += n

    def record_repair(self, n_pages: int) -> None:
        """Pages re-replicated by the repair service."""
        with self._lock:
            self.repaired_pages += n_pages

    def reset(self) -> None:
        with self._lock:
            self.rpcs = 0
            self.aggregated_rpcs = 0
            self.bytes_sent = 0
            self.data_rounds = 0
            self.metadata_rounds = 0
            self.cache_hits = 0
            self.cache_misses = 0
            self.retries = 0
            self.replica_fallbacks = 0
            self.degraded_reads = 0
            self.repaired_pages = 0
            self.per_dest_bytes.clear()
            self.per_dest_read_bytes.clear()
            self.per_dest_write_bytes.clear()


#: Serialized size of one tree node on the wire; matches the order of
#: magnitude of the paper's implementation (key + two child versions + page
#: ref + framing).
NODE_WIRE_BYTES = 64


class MetadataShard:
    """One metadata provider: an in-memory, create-only node store."""

    def __init__(self, shard_id: int) -> None:
        self.shard_id = shard_id
        self._nodes: Dict[NodeKey, TreeNode] = {}
        self.failed = False

    def put_many(self, nodes: Sequence[TreeNode]) -> None:
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        for node in nodes:
            # Create-only: concurrent writers never target the same key
            # because keys embed the (unique) version number. The sanctioned
            # re-puts are leaf rewrites that keep the page DATA identical and
            # change only placement hints: the replica balancer's
            # grown/shrunk replica sets and the repair service's
            # re-replication (both serialize on the rebalance lock), plus a
            # writer correcting its OWN still-unpublished leaves after a
            # mid-flight provider death (no one else targets those keys
            # until the version publishes).
            self._nodes[node.key] = node

    def get(self, key: NodeKey) -> Optional[TreeNode]:
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        return self._nodes.get(key)

    def get_many(self, keys: Sequence[NodeKey]) -> Dict[NodeKey, TreeNode]:
        """One aggregated RPC: every found node for ``keys`` (missing keys are
        simply absent from the result — the caller decides whether to fall
        back to a replica or error)."""
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        out: Dict[NodeKey, TreeNode] = {}
        for key in keys:
            node = self._nodes.get(key)
            if node is not None:
                out[key] = node
        return out

    def nodes_of_blob(self, blob_id: int) -> Dict[NodeKey, TreeNode]:
        if self.failed:
            raise ProviderFailed(f"metadata shard {self.shard_id} is down")
        return {k: n for k, n in list(self._nodes.items()) if k.blob_id == blob_id}

    def delete_many(self, keys: Iterable[NodeKey]) -> None:
        for key in keys:
            self._nodes.pop(key, None)

    def __len__(self) -> int:
        return len(self._nodes)


class MetadataDHT:
    """Hash-dispersed node store over ``n_shards`` metadata providers.

    ``replication`` > 1 stores each node on that many consecutive shards
    (BambooDHT-style neighbor replication); reads fall back across replicas,
    which is the paper's (inherited) metadata fault tolerance.

    ``rpc_latency_seconds`` > 0 models the wire round-trip of one *parallel
    round* of aggregated shard RPCs (the metadata half of the paper's network
    model — what the overlapped write plane hides behind the data puts): the
    concurrent per-shard RPCs of a round complete together one RTT after they
    are issued, so a round costs ONE flat sleep, not one per shard. The sleep
    holds no lock and occupies at most one pool worker, so the model adds
    latency without stealing execution resources from the real data plane.
    """

    def __init__(
        self,
        n_shards: int,
        replication: int = 1,
        stats: Optional[TrafficStats] = None,
        executor: Optional[ThreadPoolExecutor] = None,
        rpc_latency_seconds: float = 0.0,
    ) -> None:
        if replication > n_shards:
            raise ValueError("replication cannot exceed shard count")
        self.shards = [MetadataShard(i) for i in range(n_shards)]
        self.rpc_latency_seconds = rpc_latency_seconds
        self.replication = replication
        self.stats = stats or TrafficStats()
        self._executor = executor
        self._owns_executor = False
        self._executor_lock = make_lock("MetadataDHT._executor_lock")
        # group-commit state for put_nodes_coalesced: writes arriving while
        # coalesce_max_rounds rounds are already in flight pile up here and
        # ride the next round together. The bound matters both ways: with
        # unbounded rounds nothing ever coalesces (that is put_nodes_async),
        # and with ONE serialized round a lone streamer pays +0.5 RTT per
        # write for no benefit — concurrent wire RPCs genuinely overlap
        self._coalesce_lock = make_lock("MetadataDHT._coalesce_lock")
        self._coalesce_pending: List[Tuple[List[TreeNode], Future]] = []
        self._coalesce_active = 0
        self.coalesce_max_rounds = 4
        #: rounds actually flushed by the coalescer (tests assert that N
        #: concurrent small writes cost fewer than N rounds)
        self.coalesced_rounds = 0

    def _round_trip(self) -> None:
        """One modeled RTT for a parallel round of shard RPCs."""
        if self.rpc_latency_seconds > 0.0:
            time.sleep(self.rpc_latency_seconds)

    def _pool(self) -> ThreadPoolExecutor:
        with self._executor_lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=min(len(self.shards), 16)
                )
                self._owns_executor = True
            return self._executor

    def _fan_out(
        self, batches: List[Tuple[int, List[_T]]], fn: Callable[[int, List[_T]], _R]
    ) -> List[_R]:
        """Run ``fn(shard_id, batch)`` for every per-shard batch concurrently —
        one traversal level (or one writev's node set) costs ONE parallel
        round over the shards instead of a serial Python loop (paper §III.B
        "parallel per level"). A single batch skips the pool entirely."""
        if len(batches) <= 1:
            return [fn(sid, batch) for sid, batch in batches]
        futures = [self._pool().submit(fn, sid, batch) for sid, batch in batches]
        return [f.result() for f in futures]

    def close(self) -> None:
        # detach under the lock, shut down OUTSIDE it: shutdown(wait=True)
        # joins pool workers, and a worker calling _pool() while close()
        # blocks on it inside _executor_lock would deadlock
        pool: Optional[ThreadPoolExecutor] = None
        with self._executor_lock:
            if self._owns_executor and self._executor is not None:
                pool = self._executor
                self._executor = None
                self._owns_executor = False
        if pool is not None:
            pool.shutdown(wait=True)

    def _home(self, key: NodeKey) -> int:
        return hash((key.blob_id, key.version, key.offset, key.size)) % len(self.shards)

    def _replica_ids(self, key: NodeKey) -> List[int]:
        home = self._home(key)
        return [(home + r) % len(self.shards) for r in range(self.replication)]

    def put_nodes(self, nodes: Sequence[TreeNode]) -> None:
        """Store nodes, aggregating all puts to the same shard into one RPC;
        the per-shard RPCs are issued concurrently (one parallel round)."""
        by_shard: Dict[int, List[TreeNode]] = defaultdict(list)
        for node in nodes:
            for sid in self._replica_ids(node.key):
                by_shard[sid].append(node)

        def _put(sid: int, batch: List[TreeNode]) -> None:
            self.shards[sid].put_many(batch)
            self.stats.record_metadata(sid, len(batch), len(batch) * NODE_WIRE_BYTES)

        self._fan_out(list(by_shard.items()), _put)
        self._round_trip()

    def put_nodes_async(self, nodes: Sequence[TreeNode]) -> List[Future]:
        """Pipelined :meth:`put_nodes`: returns immediately with the round's
        future(s); the overlapped write plane stores a writev's metadata
        while its data puts are still in flight, joining everything only
        before ``report_success``. The round runs on ONE pool worker that
        performs the per-shard batch stores back-to-back (in-process dict
        inserts, microseconds each — fanning them out would cost more in task
        dispatch, and a worker waiting on nested futures could deadlock a
        saturated pool) and then sleeps one modeled RTT for the whole round,
        mirroring what concurrent per-shard wire RPCs would cost."""
        by_shard: Dict[int, List[TreeNode]] = defaultdict(list)
        for node in nodes:
            for sid in self._replica_ids(node.key):
                by_shard[sid].append(node)

        def _put_round() -> None:
            for sid, batch in by_shard.items():
                self.shards[sid].put_many(batch)
                self.stats.record_metadata(sid, len(batch), len(batch) * NODE_WIRE_BYTES)
            self._round_trip()

        return [self._pool().submit(_put_round)]

    def put_nodes_coalesced(self, nodes: Sequence[TreeNode]) -> List[Future]:
        """Group-commit metadata store: the cross-writev half of the paper's
        RPC aggregation. Up to ``coalesce_max_rounds`` rounds run
        concurrently (concurrent wire RPCs overlap their RTTs, exactly like
        ``put_nodes_async`` — a lightly loaded streamer keeps its latency);
        node batches from writes that arrive while all round slots are busy
        are merged into ONE per-shard batch round (one aggregated RPC per
        shard, one modeled RTT for all of them) instead of paying a shard
        round per write — the ``write_async`` window routes its writes
        through here, so a burst of small fine-grain writes shares metadata
        rounds the way one big ``writev`` always has. Returns one future
        that resolves when this call's nodes are durable; a shard failure
        fails exactly the calls that stored nodes on that shard, not the
        whole round."""
        fut: Future = Future()
        with self._coalesce_lock:
            self._coalesce_pending.append((list(nodes), fut))
            launch = self._coalesce_active < self.coalesce_max_rounds
            if launch:
                self._coalesce_active += 1
        if launch:
            try:
                self._pool().submit(self._coalesce_flush)
            except BaseException as err:
                # executor gone (shutdown race): return the slot and fail
                # whatever is queued if no live flusher remains to drain it —
                # a stranded future would hang its writer's join forever
                with self._coalesce_lock:
                    self._coalesce_active -= 1
                    stranded = []
                    if self._coalesce_active == 0:
                        stranded, self._coalesce_pending = (
                            self._coalesce_pending, []
                        )
                for _, pending_fut in stranded:
                    pending_fut.set_exception(err)
                raise
        return [fut]

    def _coalesce_flush(self) -> None:
        """Drain the coalesce queue: each loop iteration takes EVERYTHING
        queued so far as one round (per-shard aggregated stores + one RTT),
        then re-checks — writes that arrived while every round slot was busy
        ride the next loop. Runs on a pool worker per active round; the
        per-shard stores are in-process dict inserts (fanning them out would
        cost more in task dispatch than it saves, exactly like
        ``put_nodes_async``)."""
        while True:
            with self._coalesce_lock:
                batch, self._coalesce_pending = self._coalesce_pending, []
                if not batch:
                    self._coalesce_active -= 1
                    return
                self.coalesced_rounds += 1  # under the lock: flushes race
            by_shard: Dict[int, List[TreeNode]] = defaultdict(list)
            homes: List[set] = []  # per queued write, the shards it touches
            for nodes, _ in batch:
                touched: set = set()
                for node in nodes:
                    for sid in self._replica_ids(node.key):
                        by_shard[sid].append(node)
                        touched.add(sid)
                homes.append(touched)
            failed: Dict[int, BaseException] = {}
            for sid, shard_nodes in by_shard.items():
                try:
                    self.shards[sid].put_many(shard_nodes)
                    self.stats.record_metadata(
                        sid, len(shard_nodes), len(shard_nodes) * NODE_WIRE_BYTES
                    )
                except BaseException as err:
                    failed[sid] = err
            self._round_trip()
            for (_, fut), touched in zip(batch, homes):
                errs = [failed[sid] for sid in touched if sid in failed]
                if errs:
                    fut.set_exception(errs[0])
                else:
                    fut.set_result(None)

    def get_node(self, key: NodeKey) -> TreeNode:
        last_err: Optional[Exception] = None
        for sid in self._replica_ids(key):
            try:
                node = self.shards[sid].get(key)
                self.stats.record_metadata(sid, 1, NODE_WIRE_BYTES)
                self._round_trip()
            except ProviderFailed as err:  # replica fallback
                last_err = err
                continue
            if node is not None:
                return node
        if last_err is not None:
            raise last_err
        raise KeyError(f"metadata node not found: {key}")

    def get_nodes(
        self,
        keys: Sequence[NodeKey],
        on_partial: Optional[Callable[[Dict[NodeKey, TreeNode]], None]] = None,
    ) -> Dict[NodeKey, TreeNode]:
        """Batched node fetch: ONE aggregated RPC per (home) shard for the
        whole key set — the per-shard RPCs of each round run concurrently —
        with per-key replica fallback rounds on shard failure or missing
        replicas. Raises ``KeyError`` if any key is nowhere.

        ``on_partial`` switches the round into *streaming* delivery (the
        read-plane pipeline): each shard batch's found nodes are handed to
        the callback the moment that shard's RPC completes — possibly
        concurrently from pool workers, and crucially *without waiting for
        the round's slower shards* — so the caller can launch data-page
        fetches while the rest of the traversal level is still in flight.
        The modeled RTT of a streaming round elapses BEFORE the per-shard
        results are delivered (a response can only be acted on one round
        trip after the round is issued), so streaming never under-counts
        latency; the complete result dict is still returned at the end."""
        found: Dict[NodeKey, TreeNode] = {}
        pending = list(dict.fromkeys(keys))
        last_err: Optional[ProviderFailed] = None

        def _get(
            sid: int, batch: List[NodeKey]
        ) -> Tuple[List[NodeKey], Optional[Dict[NodeKey, TreeNode]], Optional[ProviderFailed]]:
            try:
                got = self.shards[sid].get_many(batch)
                self.stats.record_metadata(sid, len(batch), len(batch) * NODE_WIRE_BYTES)
                if on_partial is not None and got:
                    on_partial(got)
                return batch, got, None
            except ProviderFailed as err:
                return batch, None, err

        for round_idx in range(self.replication):
            if not pending:
                break
            by_shard: Dict[int, List[NodeKey]] = defaultdict(list)
            for key in pending:
                by_shard[self._replica_ids(key)[round_idx]].append(key)
            if on_partial is not None:
                self._round_trip()  # streaming: deliver at response-arrival time
            still_missing: List[NodeKey] = []
            for batch, got, err in self._fan_out(list(by_shard.items()), _get):
                if err is not None:
                    last_err = err
                    still_missing.extend(batch)
                    continue
                assert got is not None
                found.update(got)
                still_missing.extend(k for k in batch if k not in got)
            if on_partial is None:
                self._round_trip()
            pending = still_missing
        if pending:
            if last_err is not None:  # an outage, not a lost node
                raise last_err
            raise KeyError(f"metadata nodes not found: {pending[:3]}" +
                           (f" (+{len(pending) - 3} more)" if len(pending) > 3 else ""))
        return found

    def iter_nodes(self, blob_id: int):
        """Iterate ``(key, node)`` over every stored node of ``blob_id``,
        deduplicated across replicas (public API for GC — callers must not
        reach into shard internals)."""
        merged: Dict[NodeKey, TreeNode] = {}
        for shard in self.shards:
            try:
                merged.update(shard.nodes_of_blob(blob_id))
            except ProviderFailed:
                continue  # replicas on live shards still cover its nodes
        return iter(merged.items())

    def delete_nodes(self, keys: Iterable[NodeKey]) -> None:
        by_shard: Dict[int, List[NodeKey]] = defaultdict(list)
        for key in keys:
            for sid in self._replica_ids(key):
                by_shard[sid].append(key)
        for sid, batch in by_shard.items():
            self.shards[sid].delete_many(batch)

    def total_nodes(self) -> int:
        return sum(len(s) for s in self.shards)

    def fail_shard(self, shard_id: int) -> None:
        self.shards[shard_id].failed = True

    def recover_shard(self, shard_id: int) -> None:
        self.shards[shard_id].failed = False
