"""Copy-on-write distributed segment-tree metadata (paper §III.C).

A blob of ``total_pages`` pages (power of two) is described, for each
*version*, by a full binary tree. A node covers the segment ``(offset, size)``
(in pages): its left child covers the first half, the right child the second
half, and leaves cover exactly one page. Node identity in the metadata DHT is
``(blob_id, version, offset, size)``.

A WRITE that patches pages ``[wo, wo+ws)`` and is assigned version ``v``
creates only the nodes whose covered segment intersects the patch — the
smallest (possibly incomplete) subtree with those leaves. *Border nodes* (whose
covered segment only partially intersects the patch) are completed by linking
the missing child to the node of an **earlier** version covering that child
segment: the tree of version ``v`` is "weaved" into its predecessors, so all
unmodified metadata (and therefore data pages) are shared between snapshots.

Child links are stored as *version numbers*: the left child of inner node
``(v, o, s)`` is the node ``(left_version, o, s/2)`` and the right child is
``(right_version, o + s/2, s/2)``. A link to ``version 0`` denotes the
implicit all-zero initial string (paper §II) — no node is materialized for it.

All nodes are immutable and create-only, which is what makes readers lock-free
with respect to writers: a published version's tree can never change.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

# A page is addressed by (provider_id, page_key). page_key is globally unique.
PageRef = Tuple[int, int]

#: Version number of the implicit all-zero initial string.
ZERO_VERSION = 0


@dataclasses.dataclass(frozen=True)
class NodeKey:
    """DHT key of a metadata tree node. ``offset``/``size`` are in pages."""

    blob_id: int
    version: int
    offset: int
    size: int

    def child_keys(self, left_version: int, right_version: int) -> Tuple["NodeKey", "NodeKey"]:
        half = self.size // 2
        return (
            NodeKey(self.blob_id, left_version, self.offset, half),
            NodeKey(self.blob_id, right_version, self.offset + half, half),
        )


@dataclasses.dataclass(frozen=True)
class TreeNode:
    """An immutable metadata node.

    Leaves (``size == 1``) carry ``page`` (+ replicas) and, since the
    metadata-fault PR, an end-to-end page ``checksum``
    (:func:`repro.core.dht.page_checksum` of the page bytes, computed at
    ``writev`` freeze time and verified on every provider fetch; ``None``
    for pre-checksum nodes and inner nodes). The sanctioned
    leaf rewrites (balancer promotion, repair re-placement) go through
    ``dataclasses.replace`` and change only placement fields, so the
    checksum follows the page data it attests to.
    Inner nodes carry the versions of their two children.
    """

    key: NodeKey
    left_version: int = ZERO_VERSION
    right_version: int = ZERO_VERSION
    page: Optional[PageRef] = None
    replicas: Tuple[PageRef, ...] = ()
    checksum: Optional[int] = None

    @property
    def is_leaf(self) -> bool:
        return self.key.size == 1

    def all_page_refs(self) -> Tuple[PageRef, ...]:
        assert self.page is not None
        return (self.page,) + self.replicas


def intersects(o1: int, s1: int, o2: int, s2: int) -> bool:
    """Do half-open page intervals [o1, o1+s1) and [o2, o2+s2) intersect?"""
    return o1 < o2 + s2 and o2 < o1 + s1


@dataclasses.dataclass(frozen=True)
class BorderLink:
    """Precomputed link for a border node's missing child (paper §IV.C).

    The node covering ``(offset, size)`` of the *new* tree is incomplete; its
    missing child covering ``(child_offset, child_size)`` must point to
    ``child_version`` — the most recent version ``< v`` whose patch intersects
    the child segment (``ZERO_VERSION`` if none).
    """

    offset: int
    size: int
    child_offset: int
    child_size: int
    child_version: int


def compute_border_links(
    total_pages: int,
    write_offset: int,
    write_size: int,
    version_of_segment: Callable[[int, int], int],
) -> List[BorderLink]:
    """Compute every border link needed to weave version ``v``'s tree.

    ``version_of_segment(o, s)`` must return the most recent version ``< v``
    whose patched interval intersects ``[o, o+s)`` (``ZERO_VERSION`` if none).
    The version manager supplies this from its interval history — crucially it
    can do so even for *unpublished* concurrent writes, which is what lets
    concurrent writers weave in complete isolation (paper §IV.C).

    The walk mirrors the read traversal: starting at the root, descend into
    children that intersect the patch; a child that does not intersect the
    patch produces a :class:`BorderLink`.
    """
    links: List[BorderLink] = []

    def descend(offset: int, size: int) -> None:
        if size == 1:
            return
        half = size // 2
        lo, ls = offset, half
        ro, rs = offset + half, half
        l_hit = intersects(lo, ls, write_offset, write_size)
        r_hit = intersects(ro, rs, write_offset, write_size)
        if l_hit and not r_hit:
            links.append(BorderLink(offset, size, ro, rs, version_of_segment(ro, rs)))
        if r_hit and not l_hit:
            links.append(BorderLink(offset, size, lo, ls, version_of_segment(lo, ls)))
        if l_hit:
            descend(lo, ls)
        if r_hit:
            descend(ro, rs)

    descend(0, total_pages)
    return links


def build_write_tree(
    blob_id: int,
    version: int,
    total_pages: int,
    write_offset: int,
    write_size: int,
    leaf_pages: Sequence[Tuple[PageRef, Tuple[PageRef, ...]]],
    border_links: Sequence[BorderLink],
    leaf_checksums: Optional[Sequence[int]] = None,
) -> List[TreeNode]:
    """Materialize all nodes of version ``version``'s (incomplete) tree.

    ``leaf_pages[i]`` is ``(primary, replicas)`` for page ``write_offset+i``;
    ``leaf_checksums[i]`` (when given) is that page's integrity checksum,
    stamped onto the leaf. Returns the new nodes (leaves + inner + root);
    nothing is written to the DHT here — the caller stores them, then reports
    success to the version manager (two-phase write, paper §III.B).
    """
    border = {(b.offset, b.size): b for b in border_links}
    nodes: List[TreeNode] = []

    def descend(offset: int, size: int) -> None:
        key = NodeKey(blob_id, version, offset, size)
        if size == 1:
            i = offset - write_offset
            primary, replicas = leaf_pages[i]
            checksum = leaf_checksums[i] if leaf_checksums is not None else None
            nodes.append(
                TreeNode(
                    key, page=primary, replicas=tuple(replicas), checksum=checksum
                )
            )
            return
        half = size // 2
        lo, ls = offset, half
        ro, rs = offset + half, half
        l_hit = intersects(lo, ls, write_offset, write_size)
        r_hit = intersects(ro, rs, write_offset, write_size)
        lv = version if l_hit else border[(offset, size)].child_version
        rv = version if r_hit else border[(offset, size)].child_version
        nodes.append(TreeNode(key, left_version=lv, right_version=rv))
        if l_hit:
            descend(lo, ls)
        if r_hit:
            descend(ro, rs)

    descend(0, total_pages)
    return nodes


def traverse(
    get_node: Callable[[NodeKey], TreeNode],
    blob_id: int,
    root_version: int,
    total_pages: int,
    offset: int,
    size: int,
) -> Iterator[Tuple[int, Optional[TreeNode]]]:
    """Yield ``(page_index, leaf_or_None)`` for every page of ``[offset,
    offset+size)`` under the tree rooted at ``root_version``.

    ``None`` stands for a page of the implicit all-zero version. ``get_node``
    is the (possibly remote / DHT) node fetch; traversal issues only the node
    fetches whose segment intersects the request (paper Fig. 2a).
    """
    if root_version == ZERO_VERSION:
        for p in range(offset, offset + size):
            yield p, None
        return

    def descend(version: int, o: int, s: int) -> Iterator[Tuple[int, Optional[TreeNode]]]:
        if version == ZERO_VERSION:
            lo = max(o, offset)
            hi = min(o + s, offset + size)
            for p in range(lo, hi):
                yield p, None
            return
        node = get_node(NodeKey(blob_id, version, o, s))
        if node.is_leaf:
            yield o, node
            return
        half = s // 2
        if intersects(o, half, offset, size):
            yield from descend(node.left_version, o, half)
        if intersects(o + half, half, offset, size):
            yield from descend(node.right_version, o + half, half)

    yield from descend(root_version, 0, total_pages)


class IntervalIndex:
    """Disjoint sorted page intervals with O(log R) intersection queries.

    Built once per ``traverse_batch`` from the request's R ranges: overlapping
    and adjacent ranges are merged, then ``intersects_any``/``clip`` answer by
    bisecting the merged starts instead of rescanning all R ranges at every
    tree node (which made vectored reads O(nodes·R)).
    """

    __slots__ = ("starts", "ends")

    def __init__(self, ranges: Sequence[Tuple[int, int]]) -> None:
        merged: List[Tuple[int, int]] = []  # (start, end), half-open, disjoint
        for o, s in sorted((o, s) for o, s in ranges if s > 0):
            if merged and o <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], o + s))
            else:
                merged.append((o, o + s))
        self.starts = [m[0] for m in merged]
        self.ends = [m[1] for m in merged]

    def intersects_any(self, o: int, s: int) -> bool:
        """Does [o, o+s) intersect any requested range?"""
        # the only candidate is the last interval starting at or before o
        # (they are disjoint), plus any interval starting inside [o, o+s)
        i = bisect.bisect_right(self.starts, o) - 1
        if i >= 0 and self.ends[i] > o:
            return True
        j = i + 1
        return j < len(self.starts) and self.starts[j] < o + s

    def clip(self, o: int, s: int) -> Iterator[Tuple[int, int]]:
        """Yield the sub-intervals of [o, o+s) covered by requested ranges."""
        i = max(bisect.bisect_right(self.starts, o) - 1, 0)
        while i < len(self.starts) and self.starts[i] < o + s:
            lo = max(self.starts[i], o)
            hi = min(self.ends[i], o + s)
            if lo < hi:
                yield lo, hi
            i += 1


def traverse_batch(
    get_nodes: Callable[[Sequence[NodeKey]], "dict[NodeKey, TreeNode]"],
    blob_id: int,
    root_version: int,
    total_pages: int,
    ranges: Sequence[Tuple[int, int]],
    on_leaves: Optional[Callable[["dict[int, TreeNode]"], None]] = None,
    redirect: Optional[Callable[[int, int, int], int]] = None,
) -> "dict[int, Optional[TreeNode]]":
    """Resolve every page of several ``(offset, size)`` page ranges in ONE
    traversal pass: the tree is walked level-synchronously, and all node
    fetches of a level go through a single ``get_nodes`` call (which the
    metadata DHT aggregates into one RPC per shard). This is the metadata
    half of the batched ``readv`` data plane — N overlapping segments share
    the path nodes near the root instead of re-fetching them N times.

    Range membership queries go through an :class:`IntervalIndex` over the
    merged request ranges, so each visited node costs O(log R) instead of a
    full rescan of all R ranges.

    ``on_leaves`` is the streaming hook of the overlapped read plane: it is
    invoked with ``{page_index: leaf}`` batches of newly resolved leaves as
    each traversal level completes — before any deeper level's node fetches
    are issued — so the caller can put data-page fetches in flight while the
    remaining metadata rounds run. (A ``get_nodes`` that itself streams
    per-shard results may deliver some leaves even earlier; this hook is the
    level-granularity catch-all that works with ANY ``get_nodes``.)
    Implicit-zero pages are never emitted — there is nothing to fetch for
    them; every emitted page also appears in the returned dict.

    ``redirect`` is the dangling-link hook of writer recovery: when given,
    every child link ``(version, offset, size)`` is mapped through it before
    the zero-check or any fetch. The version manager supplies a mapping that
    sends links to *aborted* versions (holes left by failed writers whose
    neighbors had already woven border links against them) to the newest
    live version covering the segment — so a traversal never fetches a
    node of a tree that was never fully stored. Identity for live links.

    Returns ``{page_index: leaf_or_None}`` for exactly the requested pages
    (``None`` = implicit all-zero page).
    """
    index = IntervalIndex(ranges)
    out: "dict[int, Optional[TreeNode]]" = {}

    def wanted(o: int, s: int) -> bool:
        return index.intersects_any(o, s)

    def mark_zero(o: int, s: int) -> None:
        for lo, hi in index.clip(o, s):
            for p in range(lo, hi):
                out[p] = None

    if root_version == ZERO_VERSION:
        mark_zero(0, total_pages)
        return out

    frontier: List[Tuple[int, int, int]] = [(root_version, 0, total_pages)]
    while frontier:
        nodes = get_nodes([NodeKey(blob_id, v, o, s) for v, o, s in frontier])
        next_frontier: List[Tuple[int, int, int]] = []
        new_leaves: "dict[int, TreeNode]" = {}
        for v, o, s in frontier:
            node = nodes[NodeKey(blob_id, v, o, s)]
            if node.is_leaf:
                out[o] = node
                if on_leaves is not None:
                    new_leaves[o] = node
                continue
            half = s // 2
            for child_v, co in ((node.left_version, o), (node.right_version, o + half)):
                if not wanted(co, half):
                    continue
                if redirect is not None and child_v != ZERO_VERSION:
                    child_v = redirect(child_v, co, half)
                if child_v == ZERO_VERSION:
                    mark_zero(co, half)
                else:
                    next_frontier.append((child_v, co, half))
        if on_leaves is not None and new_leaves:
            on_leaves(new_leaves)
        frontier = next_frontier
    return out


def count_write_nodes(total_pages: int, write_offset: int, write_size: int) -> int:
    """Number of metadata nodes a WRITE of ``write_size`` pages creates.

    Used by benchmarks: 2·p − 1 nodes for the aligned subtree plus the path to
    the root — O(p + log total_pages), independent of blob size beyond the log
    factor (the paper's space-efficiency argument).
    """
    count = 0

    def descend(offset: int, size: int) -> None:
        nonlocal count
        count += 1
        if size == 1:
            return
        half = size // 2
        if intersects(offset, half, write_offset, write_size):
            descend(offset, half)
        if intersects(offset + half, half, write_offset, write_size):
            descend(offset + half, half)

    descend(0, total_pages)
    return count
