"""The paper's contribution: lock-free versioned blob storage.

Public API: :class:`BlobStore` (ALLOC/READ/WRITE/GC), plus the individual
actors for tests and benchmarks.
"""

from repro.core.blob import BlobStore, DEFAULT_CACHE_BYTES, ReadResult
from repro.core.dht import MetadataDHT, ProviderFailed, TrafficStats
from repro.core.flat_view import FlatView, ZERO_PAGE, flatten
from repro.core.page_cache import CacheKey, FetchPlan, PageCache
from repro.core.provider import DataProvider, ProviderManager
from repro.core.replica_balancer import BalancerConfig, ReplicaBalancer
from repro.core.segment_tree import (
    BorderLink,
    IntervalIndex,
    NodeKey,
    PageRef,
    TreeNode,
    ZERO_VERSION,
    build_write_tree,
    compute_border_links,
    count_write_nodes,
    traverse,
    traverse_batch,
)
from repro.core.version_manager import JournalEntry, VersionManager

__all__ = [
    "BlobStore",
    "DEFAULT_CACHE_BYTES",
    "ReadResult",
    "CacheKey",
    "FetchPlan",
    "PageCache",
    "MetadataDHT",
    "ProviderFailed",
    "TrafficStats",
    "FlatView",
    "ZERO_PAGE",
    "flatten",
    "DataProvider",
    "ProviderManager",
    "BalancerConfig",
    "ReplicaBalancer",
    "BorderLink",
    "IntervalIndex",
    "NodeKey",
    "PageRef",
    "TreeNode",
    "ZERO_VERSION",
    "build_write_tree",
    "compute_border_links",
    "count_write_nodes",
    "traverse",
    "traverse_batch",
    "JournalEntry",
    "VersionManager",
]
