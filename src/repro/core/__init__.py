"""The paper's contribution: lock-free versioned blob storage.

Public API: :class:`Cluster` (shared plane: version manager, metadata DHT,
data providers, replica balancer, shared cache tier) → :class:`Session`
(per-client state) → :class:`BlobHandle` (fine-grain ALLOC/READ/WRITE ops,
:class:`Snapshot` pinning, :class:`VersionWatch` subscriptions), plus the
individual actors for tests and benchmarks. :class:`BlobStore` is the
deprecated single-object facade.
"""

from repro.core.blob import BlobStore
from repro.core.cluster import (
    BlobHandle,
    Cluster,
    DEFAULT_CACHE_BYTES,
    DEFAULT_SHARED_CACHE_BYTES,
    ReadResult,
    Session,
    Snapshot,
    VersionWatch,
)
from repro.core.cluster import RetryPolicy
from repro.core.dht import (
    MetadataDHT,
    ProviderFailed,
    TrafficStats,
    page_checksum,
)
from repro.core.faults import FaultEvent, FaultInjector, FaultSchedule
from repro.core.federation import Federation, GcEpochCoordinator
from repro.core.flat_view import FlatView, ZERO_PAGE, flatten
from repro.core.page_cache import CacheKey, FetchPlan, PageCache
from repro.core.page_directory import PageAddress, PageDirectory
from repro.core.prefetch import PrefetchConfig, StridePrefetcher, WatchWarmer
from repro.core.provider import DataProvider, HealthConfig, ProviderManager
from repro.core.repair import RepairService
from repro.core.replica_balancer import BalancerConfig, ReplicaBalancer
from repro.core.segment_tree import (
    BorderLink,
    IntervalIndex,
    NodeKey,
    PageRef,
    TreeNode,
    ZERO_VERSION,
    build_write_tree,
    compute_border_links,
    count_write_nodes,
    traverse,
    traverse_batch,
)
from repro.core.version_manager import (
    JournalEntry,
    VersionAbandoned,
    VersionManager,
)

__all__ = [
    "BlobHandle",
    "BlobStore",
    "Cluster",
    "DEFAULT_CACHE_BYTES",
    "DEFAULT_SHARED_CACHE_BYTES",
    "ReadResult",
    "RetryPolicy",
    "Session",
    "Snapshot",
    "VersionWatch",
    "FaultEvent",
    "FaultInjector",
    "FaultSchedule",
    "Federation",
    "GcEpochCoordinator",
    "HealthConfig",
    "RepairService",
    "CacheKey",
    "FetchPlan",
    "PageAddress",
    "PageCache",
    "PageDirectory",
    "PrefetchConfig",
    "StridePrefetcher",
    "WatchWarmer",
    "MetadataDHT",
    "ProviderFailed",
    "TrafficStats",
    "page_checksum",
    "FlatView",
    "ZERO_PAGE",
    "flatten",
    "DataProvider",
    "ProviderManager",
    "BalancerConfig",
    "ReplicaBalancer",
    "BorderLink",
    "IntervalIndex",
    "NodeKey",
    "PageRef",
    "TreeNode",
    "ZERO_VERSION",
    "build_write_tree",
    "compute_border_links",
    "count_write_nodes",
    "traverse",
    "traverse_batch",
    "JournalEntry",
    "VersionAbandoned",
    "VersionManager",
]
