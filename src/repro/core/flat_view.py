"""Tree → flat page-table flattening: the TPU hardware adaptation seam.

TPU cores cannot chase DHT pointers, so the device-facing view of a blob
version is a *flat page table*: for each page of a requested range, the
``(provider_id, page_key)`` pair, as int32 numpy arrays. The host resolves the
segment tree once per (version, range); devices then perform O(1) indexed
gathers — this is exactly how the serving engine turns the paper's metadata
scheme into something a Pallas kernel can consume (see
``storage/kvcache.py`` and ``kernels/paged_attention``).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.core.segment_tree import traverse

if TYPE_CHECKING:
    from repro.core.cluster import Cluster

#: Sentinel for pages of the implicit all-zero version.
ZERO_PAGE = -1


@dataclasses.dataclass
class FlatView:
    """Device-consumable description of ``[first_page, first_page+n)`` of one
    published version of a blob."""

    blob_id: int
    version: int
    first_page: int
    provider_ids: np.ndarray  # int32 (n,)  ZERO_PAGE for implicit zero pages
    page_keys: np.ndarray  # int32 (n,)

    @property
    def n_pages(self) -> int:
        return int(self.page_keys.shape[0])


def flatten(
    cluster: "Cluster", blob_id: int, version: int, first_page: int, n_pages: int
) -> FlatView:
    """Resolve ``n_pages`` of one published version to (provider, key) pairs.
    ``cluster`` is the shared plane (anything exposing ``version_manager``
    and ``metadata`` works, including the deprecated ``BlobStore``)."""
    total_pages, _ = cluster.version_manager.blob_info(blob_id)
    if version > cluster.version_manager.latest_published(blob_id):
        raise ValueError(f"version {version} not yet published")
    provider_ids = np.full(n_pages, ZERO_PAGE, dtype=np.int32)
    page_keys = np.full(n_pages, ZERO_PAGE, dtype=np.int32)
    for page_index, leaf in traverse(
        cluster.metadata.get_node, blob_id, version, total_pages, first_page, n_pages
    ):
        if leaf is not None:
            pid, key = leaf.page  # type: ignore[misc]
            provider_ids[page_index - first_page] = pid
            page_keys[page_index - first_page] = key
    return FlatView(blob_id, version, first_page, provider_ids, page_keys)
