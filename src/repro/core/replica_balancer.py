"""Adaptive hot-page replication (BlobSeer-style dynamic replication).

The paper's placement spreads *writes* evenly, but a skewed read workload
(every client hammering the same few pages — the supernovae detector's hot sky
windows) still funnels all fetches to whichever providers happen to hold the
hot pages: aggregate read bandwidth collapses to a handful of providers'
service capacity. BlobSeer's answer, reproduced here, is to watch the
per-provider read-traffic skew and *promote* hot pages onto extra providers,
so the replica-spreading read path (``Session._fetch_pages``) can fan
hot traffic out across the cluster; promotions are demoted (the extra copies
dropped) when GC collects the version or when callers demote explicitly.

Safety: data pages are immutable, so copying one to another provider and
re-putting its leaf node with a *grown* replica tuple never changes what a
reader observes — at worst a reader holds the older node and simply doesn't
know about the new replica yet. Node rewrites are serialized on the
balancer's rebalance lock, preserving the DHT's "no concurrent writes to one
key" discipline.

Locking: the read path only ever touches ``_heat_lock``, whose critical
sections are a few dict operations — never a network copy. Promotion passes
serialize on a separate non-blocking ``_rebalance_lock`` and perform their
page copies with no lock held, so readers are never queued behind a
promotion (that would re-serialize the very path this module parallelizes).
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from repro.analysis.lockwatch import make_lock
from repro.core.dht import MetadataDHT, ProviderFailed, TrafficStats
from repro.core.provider import ProviderManager
from repro.core.segment_tree import NodeKey, PageRef, TreeNode


@dataclasses.dataclass(frozen=True)
class BalancerConfig:
    """Knobs for hot-page promotion.

    ``hot_threshold``: provider fetches of a page (since its counter last
    decayed) before it is promotion-eligible. ``skew_ratio``: promote only
    while the busiest provider's read bytes exceed this multiple of the mean.
    ``check_interval``: how many noted page-fetches between rebalance passes.
    ``max_extra_replicas``: cap of *promoted* copies per page, on top of the
    write-time replication. ``max_promotions_per_pass`` bounds the work one
    unlucky reader thread can absorb.
    """

    hot_threshold: int = 4
    skew_ratio: float = 1.5
    check_interval: int = 64
    max_extra_replicas: int = 3
    max_promotions_per_pass: int = 8


class ReplicaBalancer:
    """Watches read skew and replicates hot pages onto cold providers."""

    def __init__(
        self,
        provider_manager: ProviderManager,
        metadata: MetadataDHT,
        stats: TrafficStats,
        config: Optional[BalancerConfig] = None,
    ) -> None:
        self.providers = provider_manager
        self.metadata = metadata
        self.stats = stats
        self.config = config or BalancerConfig()
        #: guards _heat/_promoted/_since_check; held only for dict ops
        self._heat_lock = make_lock("ReplicaBalancer._heat_lock")
        #: serializes promotion/demotion passes (and their node rewrites);
        #: the read path never blocks on it
        self._rebalance_lock = make_lock("ReplicaBalancer._rebalance_lock")
        #: per-leaf fetch counters + the freshest node observed for that key
        self._heat: Dict[NodeKey, Tuple[int, TreeNode]] = {}
        #: promoted (extra) replicas per leaf — the only ones demote may drop
        self._promoted: Dict[NodeKey, List[PageRef]] = {}
        self._since_check = 0
        self.promotions = 0
        self.demotions = 0
        self._rng = random.Random(0x5EED)

    # -- read-path hooks ---------------------------------------------------
    def note_fetches(self, leaves: Iterable[TreeNode]) -> None:
        """Record that these leaves' pages were fetched from providers (cache
        hits never reach here — RAM hits need no rebalancing). Cheap: one lock
        pass of counter bumps; every ``check_interval`` noted fetches the
        caller runs one rebalance pass inline (skipped without blocking if a
        pass is already running on another thread)."""
        run_pass = False
        with self._heat_lock:
            for leaf in leaves:
                count, known = self._heat.get(leaf.key, (0, leaf))
                # our own promote/demote rewrites are the only mutations a
                # leaf ever sees, so the node already recorded here is always
                # at least as fresh as a reader's copy — never replace it
                # (a reader's pre-demotion node would resurrect dropped refs)
                self._heat[leaf.key] = (count + 1, known)
                self._since_check += 1
            if self._since_check >= self.config.check_interval:
                self._since_check = 0
                run_pass = True
        if run_pass:
            self.rebalance()

    # -- promotion / demotion ----------------------------------------------
    def rebalance(self) -> int:
        """One promotion pass; returns how many pages were promoted.

        Only one thread rebalances at a time (non-blocking for the rest), and
        the page copies run with no lock held, so read latency never stacks
        behind a queue of passes.
        """
        if not self._rebalance_lock.acquire(blocking=False):
            return 0
        try:
            read_bytes = self.stats.read_bytes_snapshot()
            live = {p.provider_id for p in self.providers.providers()}
            if not read_bytes or len(live) < 2:
                return 0
            mean = sum(read_bytes.values()) / max(len(live), 1)
            if mean <= 0:
                return 0
            hot_providers = {
                pid for pid, b in read_bytes.items()
                if b > self.config.skew_ratio * mean
            }
            with self._heat_lock:
                # hottest pages first, only those served from a skewed
                # provider and not already replicated to the cap
                candidates = sorted(
                    (
                        (count, key, node)
                        for key, (count, node) in self._heat.items()
                        if count >= self.config.hot_threshold
                        and len(self._promoted.get(key, []))
                        < self.config.max_extra_replicas
                    ),
                    key=lambda t: -t[0],
                )
            promoted = 0
            for count, key, node in candidates:
                if promoted >= self.config.max_promotions_per_pass:
                    break
                if hot_providers and not (
                    {pid for pid, _ in node.all_page_refs()} & hot_providers
                ):
                    continue
                new_ref, new_node = self._promote(node)
                if new_node is not None:
                    assert new_ref is not None
                    with self._heat_lock:
                        self._promoted.setdefault(key, []).append(new_ref)
                        self._heat[key] = (0, new_node)
                    self.promotions += 1
                    promoted += 1
            with self._heat_lock:
                # decay so yesterday's hot pages don't stay eligible forever
                self._heat = {
                    k: (c // 2, n)
                    for k, (c, n) in self._heat.items()
                    if c // 2 > 0 or k in self._promoted
                }
            return promoted
        finally:
            self._rebalance_lock.release()

    def _promote(
        self, node: TreeNode
    ) -> Tuple[Optional[PageRef], Optional[TreeNode]]:
        """Copy ``node``'s page to the least-loaded provider not already
        serving it and re-put the leaf with the grown replica set. Runs under
        ``_rebalance_lock`` only — the copy is pure data-plane traffic."""
        serving = [pid for pid, _ in node.all_page_refs()]
        target_pid = self.providers.least_loaded(exclude=serving)
        if target_pid is None:
            return None, None
        page = None
        for pid, page_key in node.all_page_refs():
            try:
                provider = self.providers.get_provider(pid)
                page = provider.get_page(page_key)
                break
            except (ProviderFailed, KeyError):
                continue
        if page is None:
            return None, None  # every current replica is dark; nothing to copy
        assert node.page is not None
        page_key = node.page[1]  # replicas share the primary's page key
        new_ref: PageRef = (target_pid, page_key)
        try:
            self.providers.get_provider(target_pid).put_pages([(page_key, page)])
        except (ProviderFailed, KeyError):
            return None, None
        self.providers.add_load(target_pid)
        new_node = dataclasses.replace(node, replicas=node.replicas + (new_ref,))
        self.metadata.put_nodes([new_node])
        return new_ref, new_node

    def demote(self, key: NodeKey) -> int:
        """Drop every *promoted* replica of leaf ``key`` (write-time replicas
        stay): delete the copies, return their load credit, re-put the leaf
        with the shrunken replica set. Returns how many copies were dropped."""
        with self._rebalance_lock:
            with self._heat_lock:
                extras = self._promoted.pop(key, [])
                entry = self._heat.get(key)
            if not extras:
                return 0
            node = entry[1] if entry is not None else None
            if node is None:
                try:
                    node = self.metadata.get_node(key)
                except (KeyError, ProviderFailed):
                    node = None
            for pid, page_key in extras:
                try:
                    self.providers.get_provider(pid).delete_pages([page_key])
                except KeyError:
                    pass
            self.providers.release(extras)
            if node is not None:
                kept = tuple(r for r in node.replicas if r not in set(extras))
                new_node = dataclasses.replace(node, replicas=kept)
                self.metadata.put_nodes([new_node])
                with self._heat_lock:
                    if key in self._heat:
                        self._heat[key] = (self._heat[key][0], new_node)
            self.demotions += len(extras)
            return len(extras)

    # -- GC coherence --------------------------------------------------------
    @contextlib.contextmanager
    def paused(self) -> Iterator[None]:
        """Block promotion/demotion passes for the duration (GC uses this so
        an in-flight promotion can't re-create a node GC just deleted or copy
        a page GC is about to drop)."""
        with self._rebalance_lock:
            yield

    def forget(self, keys: Iterable[NodeKey]) -> None:
        """GC collected these leaves: drop their heat and promotion records.
        (The promoted page copies themselves are already deleted by GC — they
        appear in the rewritten nodes' ``all_page_refs``.)"""
        with self._heat_lock:
            for key in keys:
                self._heat.pop(key, None)
                self._promoted.pop(key, None)

    # -- introspection -------------------------------------------------------
    def hottest_page_offsets(self, blob_id: int, k: int) -> List[int]:
        """Top-``k`` page offsets of ``blob_id`` by provider-fetch heat,
        aggregated across versions (pages are COW-rewritten under new
        versions but their *offsets* keep their access skew). This is the
        watch-warmer's prior for which pages of a freshly published version
        detectors will pull first; ties break low-offset-first so the order
        is deterministic."""
        with self._heat_lock:
            agg: Dict[int, int] = {}
            for key, (count, _) in self._heat.items():
                if key.blob_id == blob_id:
                    agg[key.offset] = agg.get(key.offset, 0) + count
        return sorted(agg, key=lambda o: (-agg[o], o))[:k]

    def promoted_refs(self, key: NodeKey) -> Tuple[PageRef, ...]:
        with self._heat_lock:
            return tuple(self._promoted.get(key, ()))

    def n_tracked(self) -> int:
        with self._heat_lock:
            return len(self._heat)
