"""Incremental, versioned, crash-consistent checkpointing on the blob store.

The training state (params + optimizer) is serialized into ONE logical blob
with a page-aligned layout. Each checkpoint WRITEs only the *dirty* pages
(content hash changed since the previous version) — the paper's patching —
so consecutive checkpoints share all unchanged pages (COW), old checkpoints
stay readable while the next one is being written (read/write concurrency),
and a checkpoint becomes visible only when its last write publishes
(atomicity: a crash mid-save leaves the previous version intact).

Restore can target any retained step and reshard to a different mesh — the
blob is mesh-agnostic bytes; elasticity comes for free.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.lockwatch import make_lock
from repro.core.cluster import Session


@dataclasses.dataclass
class LeafInfo:
    path: str
    offset: int  # byte offset in the blob (page aligned)
    size: int
    dtype: str
    shape: Tuple[int, ...]


@dataclasses.dataclass
class CheckpointRecord:
    step: int
    version: int  # blob version at which this checkpoint is complete
    dirty_pages: int
    total_pages: int


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(k), v) for k, v in flat]


class BlobCheckpointer:
    def __init__(
        self,
        session: Session,
        template: Any,
        page_size: int = 1 << 20,
        keep_last: int = 3,
    ) -> None:
        self.session = session
        self.page_size = page_size
        self.keep_last = keep_last
        self._lock = make_lock("BlobCheckpointer._lock")

        leaves = _leaf_paths(template)
        self.layout: List[LeafInfo] = []
        off = 0
        for path, leaf in leaves:
            size = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize if leaf.shape else np.dtype(leaf.dtype).itemsize
            self.layout.append(LeafInfo(path, off, size, str(leaf.dtype), tuple(leaf.shape)))
            off += -(-size // page_size) * page_size  # page-align every leaf
        total = max(off, page_size)
        # blob sizes are powers of two (paper §II)
        self.blob_bytes = 1 << (total - 1).bit_length()
        self.handle = session.create(self.blob_bytes, page_size)
        self.blob_id = self.handle.blob_id
        self.n_pages = self.blob_bytes // page_size
        self._page_hash: Dict[int, bytes] = {}
        self.checkpoints: List[CheckpointRecord] = []
        self._treedef = jax.tree.structure(template)

    # -- save -------------------------------------------------------------------------
    def save(self, step: int, state: Any) -> CheckpointRecord:
        """Write dirty pages of ``state``; returns the checkpoint record."""
        with self._lock:
            leaves = _leaf_paths(state)
            assert len(leaves) == len(self.layout), "state structure changed"
            dirty_runs: List[Tuple[int, bytes]] = []  # (page_index, page_bytes...)
            dirty = 0
            total_pages_touched = 0
            ps = self.page_size

            run_start: Optional[int] = None
            run_chunks: List[bytes] = []

            def flush_run():
                nonlocal run_start, run_chunks
                if run_start is not None:
                    dirty_runs.append((run_start, b"".join(run_chunks)))
                run_start, run_chunks = None, []

            for info, (path, leaf) in zip(self.layout, leaves):
                arr = np.ascontiguousarray(jax.device_get(leaf))
                raw = arr.tobytes()
                n_pages = -(-len(raw) // ps)
                total_pages_touched += n_pages
                first_page = info.offset // ps
                for p in range(n_pages):
                    chunk = raw[p * ps : (p + 1) * ps]
                    if len(chunk) < ps:
                        chunk = chunk + b"\0" * (ps - len(chunk))
                    h = hashlib.blake2b(chunk, digest_size=16).digest()
                    page_idx = first_page + p
                    if self._page_hash.get(page_idx) == h:
                        flush_run()
                        continue
                    self._page_hash[page_idx] = h
                    dirty += 1
                    if run_start is None:
                        run_start = page_idx
                    elif run_start + len(run_chunks) != page_idx:
                        flush_run()
                        run_start = page_idx
                    run_chunks.append(chunk)
                flush_run()

            version = self.handle.latest_published()
            for page_idx, data in dirty_runs:
                buf = np.frombuffer(data, dtype=np.uint8)
                version = self.handle.write(buf, page_idx * ps)

            rec = CheckpointRecord(step, version, dirty, total_pages_touched)
            self.checkpoints.append(rec)
            self._gc()
            return rec

    def save_async(self, step: int, state: Any) -> threading.Thread:
        """Snapshot to host then write in a background thread (training
        proceeds concurrently — the paper's read/write concurrency)."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        t = threading.Thread(target=self.save, args=(step, host_state), daemon=True)
        t.start()
        return t

    # -- restore ----------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, shardings: Any = None) -> Any:
        """Rebuild the state pytree from the blob (any retained step).

        ``shardings``: optional pytree of NamedShardings to reshard onto a
        (possibly different) mesh — elastic restart.
        """
        with self._lock:
            if not self.checkpoints:
                raise RuntimeError("no checkpoints saved")
            if step is None:
                rec = self.checkpoints[-1]
            else:
                rec = next(c for c in self.checkpoints if c.step == step)
        leaves = []
        for info in self.layout:
            res = self.handle.read(info.offset, info.size, version=rec.version)
            arr = np.frombuffer(res.data.tobytes(), dtype=info.dtype).reshape(info.shape)
            leaves.append(arr)
        state = jax.tree.unflatten(self._treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    # -- retention ----------------------------------------------------------------------
    def _gc(self) -> None:
        if len(self.checkpoints) <= self.keep_last:
            return
        keep = self.checkpoints[-self.keep_last :]
        self.session.cluster.gc(self.blob_id, [c.version for c in keep])
        self.checkpoints = keep

    def manifest(self) -> str:
        return json.dumps(
            {
                "blob_id": self.blob_id,
                "page_size": self.page_size,
                "checkpoints": [dataclasses.asdict(c) for c in self.checkpoints],
            }
        )
