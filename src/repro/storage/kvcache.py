"""Host-side paged-KV allocator: the paper's provider-manager + metadata
control plane, applied to serving.

The device holds the page pools (jax arrays, striped over the mesh); this
allocator owns the *page-id space* and implements:

* **placement** — pages for a request come from a free list (the provider
  manager's load-balanced allocation; ids map to shards by range, so a
  request's pages land device-local when possible);
* **prefix sharing** — full pages of a prompt are content-addressed by the
  token chain hash; matching prefixes share pages read-only (the paper's
  "sharing common parts of snapshots" — space efficiency across snapshots);
* **COW** — a shared page is never written: the engine gets a
  ``(src, dst)`` copy list to fork the page before a request appends into it
  (exactly the paper's WRITE: fresh pages, old versions stay readable);
* **versioning** — a sequence snapshot is its immutable page-table tuple +
  length; snapshots taken at any point remain valid until released
  (read/write concurrency: a snapshot reader is never invalidated by the
  writer's progress).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


def chain_hash(prev: int, tokens: Tuple[int, ...]) -> int:
    """Content address of a token run given its prefix hash. Module-level so
    the blob-backed serving plane (``repro.serving.blob_kv``) addresses pages
    identically to the host allocator — int/tuple hashing is deterministic
    within a process, which is the sharing domain of both indexes."""
    return hash((prev, tokens))


@dataclasses.dataclass
class SeqState:
    seq_id: int
    length: int  # tokens written so far
    pages: List[int]  # page ids, in positional order (no ring here: engine decode grows)
    shared_prefix_pages: int  # first N pages are shared (read-only)


@dataclasses.dataclass
class Snapshot:
    seq_id: int
    length: int
    pages: Tuple[int, ...]


class PagedKVAllocator:
    """Page bookkeeping for one pool (all layers share the id space; the
    device pools are stacked (L, P, ...) so one id addresses all layers)."""

    def __init__(self, n_pages: int, page_tokens: int) -> None:
        self.n_pages = n_pages
        self.T = page_tokens
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        #: prefix hash -> page id (content-addressed full pages)
        self._prefix_index: Dict[int, int] = {}
        self._page_prefix: Dict[int, int] = {}  # reverse map for eviction
        #: full-page-prefix hash -> {pid: tokens written so far in that page}
        #: for PARTIAL final pages; unlike _prefix_index these entries hold no
        #: reference — they live exactly as long as their owner's page does
        self._ext_index: Dict[int, Dict[int, Tuple[int, ...]]] = {}
        self._page_ext: Dict[int, int] = {}  # reverse map for cleanup
        self._seqs: Dict[int, SeqState] = {}
        self._next_seq = 0
        self.stats = {
            "alloc": 0, "shared": 0, "cow_copies": 0, "freed": 0,
            "partial_shared_tokens": 0,
        }

    # -- low-level ----------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def _alloc_page(self) -> int:
        if not self._free:
            # evict an unreferenced prefix-cache page if any (_release_page
            # drops the prefix-index entry when the last ref is the cache's)
            for h, pid in list(self._prefix_index.items()):
                if self._ref.get(pid, 0) == 1 and self._page_prefix.get(pid) == h:
                    self._release_page(pid)
                    break
            if not self._free:
                raise MemoryError("KV pool exhausted")
        pid = self._free.pop()
        self._ref[pid] = 1
        self.stats["alloc"] += 1
        return pid

    def _retain(self, pid: int) -> None:
        self._ref[pid] += 1

    def _release_page(self, pid: int) -> None:
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            del self._ref[pid]
            h = self._page_prefix.pop(pid, None)
            if h is not None:
                self._prefix_index.pop(h, None)
            eh = self._page_ext.pop(pid, None)
            if eh is not None:
                bucket = self._ext_index.get(eh)
                if bucket is not None:
                    bucket.pop(pid, None)
                    if not bucket:
                        del self._ext_index[eh]
            self._free.append(pid)
            self.stats["freed"] += 1

    # -- prefix hashing --------------------------------------------------------------
    _chain = staticmethod(chain_hash)

    # -- request lifecycle --------------------------------------------------------------
    def admit(self, tokens: Sequence[int]) -> Tuple[SeqState, int, List[Tuple[int, int]]]:
        """Admit a prompt. Returns (seq, n_shared_tokens, cow_copies).

        ``n_shared_tokens`` tokens are already present in shared pages (the
        engine can skip prefill WRITES for them); ``cow_copies`` is a list of
        (src_page, dst_page) the engine must copy on device BEFORE its next
        allocator call (COW fork of a partially-reused page).

        Partial-page reuse: when the prompt *ends* inside its final page and
        another live sequence's final page starts with those same tokens
        (under the same full-page prefix), that page is COW-forked into the
        new sequence and the whole prompt counts as shared — the fork's
        positions beyond the prompt are stale KV from the donor, masked by
        this sequence's length and overwritten as decode appends. A prompt
        whose tail spans past the matched page gets no partial reuse: the
        engine would have to scatter recomputed KV over the fork anyway.
        """
        tokens = tuple(int(t) for t in tokens)
        T = self.T
        pages: List[int] = []
        shared = 0
        h = 0
        # longest shared full-page prefix
        while (shared + 1) * T <= len(tokens):
            h2 = self._chain(h, tokens[shared * T : (shared + 1) * T])
            pid = self._prefix_index.get(h2)
            if pid is None:
                break
            self._retain(pid)
            pages.append(pid)
            shared += 1
            h = h2
        n_shared_tokens = shared * T

        cow: List[Tuple[int, int]] = []
        rest = len(tokens) - n_shared_tokens
        tail = tokens[n_shared_tokens:]
        if 0 < rest < T:
            # the prompt ends in this page: a donor page whose first `rest`
            # tokens match lets us fork instead of prefilling the page
            for src, src_tokens in self._ext_index.get(h, {}).items():
                if len(src_tokens) >= rest and src_tokens[:rest] == tail:
                    dst = self._alloc_page()
                    cow.append((src, dst))
                    pages.append(dst)
                    n_shared_tokens = len(tokens)
                    rest = 0
                    self.stats["cow_copies"] += 1
                    self.stats["partial_shared_tokens"] += len(tail)
                    break

        # fresh pages for the rest of the prompt (+ the decode head page)
        n_fresh = (rest + T - 1) // T
        for i in range(n_fresh):
            pid = self._alloc_page()
            pages.append(pid)
        # register newly-written full pages in the prefix index
        hh = h
        for i in range(shared, len(tokens) // T):
            hh = self._chain(hh, tokens[i * T : (i + 1) * T])
            pid = pages[i]
            if hh not in self._prefix_index:
                self._prefix_index[hh] = pid
                self._page_prefix[pid] = hh
                self._retain(pid)  # the index holds a reference
        # index a partial final page as a COW donor for later admits (no
        # reference held: the entry dies with the page)
        if len(tokens) % T and pages:
            head = pages[-1]
            if head not in self._page_ext:
                self._page_ext[head] = hh
                self._ext_index.setdefault(hh, {})[head] = tokens[
                    (len(tokens) // T) * T:
                ]

        seq = SeqState(self._next_seq, len(tokens), pages, shared)
        self._next_seq += 1
        self._seqs[seq.seq_id] = seq
        self.stats["shared"] += shared
        return seq, n_shared_tokens, cow

    def fork_for_batch(self, seq_id: int, busy) -> List[Tuple[int, int]]:
        """COW-fork any of this sequence's pages whose id is in ``busy`` (the
        pages of every OTHER live row of the same decode batch). The
        owner-indexed attention kernel (kernels/ops.py ``page_ownership``)
        assigns each pool page to exactly one row per batch, so two live rows
        must never alias a page id: prefix sharing is storage-level across
        time, and concurrent readers of a shared page each get a device copy.
        Returns the (src, dst) device copies; raises ``MemoryError`` with the
        sequence still internally consistent (caller rolls back via
        ``finish``)."""
        seq = self._seqs[seq_id]
        copies: List[Tuple[int, int]] = []
        for i, pid in enumerate(seq.pages):
            if pid in busy:
                dst = self._alloc_page()
                copies.append((pid, dst))
                seq.pages[i] = dst
                self._release_page(pid)
                self.stats["cow_copies"] += 1
        return copies

    def ensure_writable_head(self, seq_id: int) -> List[Tuple[int, int]]:
        """Before decode appends to the head page, COW-fork it if shared.
        Returns device copies (src, dst) to perform."""
        seq = self._seqs[seq_id]
        copies: List[Tuple[int, int]] = []
        head = seq.length // self.T
        if head >= len(seq.pages):
            seq.pages.append(self._alloc_page())
            return copies
        pid = seq.pages[head]
        if self._ref.get(pid, 1) > 1:
            fresh = self._alloc_page()
            copies.append((pid, fresh))
            self._release_page(pid)
            seq.pages[head] = fresh
            self.stats["cow_copies"] += 1
        return copies

    def append_token(self, seq_id: int) -> List[Tuple[int, int]]:
        """Account one decoded token; returns required COW copies / growth."""
        copies = self.ensure_writable_head(seq_id)
        self._seqs[seq_id].length += 1
        return copies

    def snapshot(self, seq_id: int) -> Snapshot:
        """Immutable snapshot (the paper's published version): retains every
        page so later writes/frees cannot disturb readers."""
        seq = self._seqs[seq_id]
        for pid in seq.pages:
            self._retain(pid)
        return Snapshot(seq_id, seq.length, tuple(seq.pages))

    def release_snapshot(self, snap: Snapshot) -> None:
        for pid in snap.pages:
            self._release_page(pid)

    def finish(self, seq_id: int) -> None:
        seq = self._seqs.pop(seq_id)
        for pid in seq.pages:
            self._release_page(pid)

    def table(self, seq_id: int, max_pages: int) -> List[int]:
        """Page table row padded to ``max_pages`` (device shape). Padding uses
        the out-of-bounds sentinel ``n_pages`` so ownership scatters drop it
        (a 0 pad would falsely claim page 0)."""
        seq = self._seqs[seq_id]
        pad = [self.n_pages] * (max_pages - len(seq.pages))
        return list(seq.pages) + pad

    def used_pages(self) -> int:
        return self.n_pages - len(self._free)
