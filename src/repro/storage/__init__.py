from repro.storage.checkpoint import BlobCheckpointer, CheckpointRecord
from repro.storage.kvcache import PagedKVAllocator, SeqState, Snapshot, chain_hash

__all__ = [
    "BlobCheckpointer",
    "CheckpointRecord",
    "PagedKVAllocator",
    "SeqState",
    "Snapshot",
    "chain_hash",
]
