"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

The chunked SSD formulation: split the sequence into chunks of length L;
within a chunk the output is a masked (decay-weighted) attention-like matmul
(MXU-friendly); across chunks a small recurrent state (H, P, N) is carried by
a scan. Decode is the O(1) recurrent update — attention-free, which is what
makes ``long_500k`` trivial for this family.

TPU adaptation note: the CUDA Mamba2 kernel fuses the chunk scan; here the
intra-chunk term is expressed as batched matmuls (MXU) and the inter-chunk
recurrence as a ``lax.scan`` over chunk states — the natural TPU mapping.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.modules import dense_init


def ssm_init(key, cfg: ModelConfig):
    d, di, n, g = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    h, dconv, dt = cfg.ssm_heads, cfg.ssm_conv, cfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    conv_dim = di + 2 * g * n
    params = {
        "in_proj": dense_init(k1, d, (2 * di + 2 * g * n + h,), dt),
        "conv_w": (jax.random.normal(k2, (dconv, conv_dim), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": dense_init(k3, di, (d,), dt),
    }
    axes = {
        "in_proj": ("embed", "ssm_proj"),
        "conv_w": ("conv", "ssm_conv_dim"),
        "conv_b": ("ssm_conv_dim",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, axes


def _split_proj(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    di, n, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, C) with taps (Kc, C)."""
    Kc = w.shape[0]
    out = xBC * w[-1]
    for i in range(1, Kc):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, : xBC.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + b


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise decay: out[..., i, j] = Σ_{j<m<=i} a[..., m].

    a: (..., L) → (..., L, L) with -inf above the diagonal.
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) — post-softplus
    A: jnp.ndarray,  # (H,) negative decay rates
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, N, P)
    return_state: bool = False,
):
    """Chunked SSD scan. Returns y (B,S,H,P) [, final_state]."""
    Bb, S, H, Pd = x.shape
    G = Bm.shape[2]
    L = min(chunk, S)
    if S % L:  # fall back to the largest divisor of S not exceeding `chunk`
        L = next(c for c in range(L, 0, -1) if S % c == 0)
    nc = S // L
    rep = H // G

    xc = x.reshape(Bb, nc, L, H, Pd)
    dtc = dt.reshape(Bb, nc, L, H)
    Bc = Bm.reshape(Bb, nc, L, G, N := Bm.shape[-1])
    Cc = Cm.reshape(Bb, nc, L, G, N)

    a = dtc * A  # (B, nc, L, H) log decay per step (fp32)
    cum_a = jnp.cumsum(a, axis=2)  # within-chunk cumulative

    # ---- intra-chunk (quadratic in L, MXU matmuls) ----
    ct = xc.dtype
    seg = _segsum(jnp.moveaxis(a, -1, -2))  # (B, nc, H, L, L)
    decay = jnp.exp(seg).astype(ct)
    scores = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc)  # (B,nc,G,L,L)
    scores = jnp.repeat(scores.astype(ct), rep, axis=2)  # → (B,nc,H,L,L)
    M = scores * decay
    xdt = (xc * dtc[..., None].astype(ct)).astype(ct)  # (B,nc,L,H,P)
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", M, xdt, preferred_element_type=jnp.float32)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cum_a[:, :, -1:, :] - cum_a)  # (B,nc,L,H)
    states = jnp.einsum(
        "bclgn,bclh,bclhp->bchnp",
        Bc, (decay_to_end * dtc).astype(ct), xc,
        preferred_element_type=jnp.float32,
    )

    # ---- inter-chunk recurrence over nc (small state) ----
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])  # (B, nc, H)

    def body(s, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        s_next = s * dec[:, :, None, None] + st
        return s_next, s  # emit the state *entering* this chunk

    s0 = init_state if init_state is not None else jnp.zeros((Bb, H, N, Pd), jnp.float32)
    final, prev_states = lax.scan(
        body,
        s0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, N, P)

    state_decay = jnp.exp(cum_a)  # decay from chunk start to position
    Cr = jnp.repeat(Cc, rep, axis=3)  # (B,nc,L,H,N)
    y_inter = jnp.einsum(
        "bclhn,bchnp,bclh->bclhp",
        Cr, prev_states.astype(ct), state_decay.astype(ct),
        preferred_element_type=jnp.float32,
    )

    y = (y_intra + y_inter).reshape(Bb, S, H, Pd)
    if return_state:
        return y, final
    return y


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, N, P)
    x: jnp.ndarray,  # (B, H, P)
    dt: jnp.ndarray,  # (B, H) post-softplus
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, G, N)
    Cm: jnp.ndarray,  # (B, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent update: returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Br = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Cr = jnp.repeat(Cm, rep, axis=1)
    decay = jnp.exp(dt * A)  # (B,H)
    upd = jnp.einsum("bhn,bhp->bhnp", Br, x * dt[..., None])
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cr, new_state)
    return y, new_state


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    yf = yf * lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + eps)
    return (yf * scale.astype(jnp.float32)).astype(y.dtype)


def ssm_forward(
    params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
) -> jnp.ndarray:
    """Full-sequence Mamba2 mixer (train / prefill)."""
    ct = cfg.cdtype()
    di, n, g, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(ct))
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(ct), params["conv_b"].astype(ct)))
    xs = xBC[..., :di].reshape(*xBC.shape[:2], h, p)
    Bm = xBC[..., di : di + g * n].reshape(*xBC.shape[:2], g, n)
    Cm = xBC[..., di + g * n :].reshape(*xBC.shape[:2], g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = _gated_norm(y.reshape(*x.shape[:2], di).astype(ct), z, params["norm"])
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(ct))


SSMState = Dict[str, jnp.ndarray]  # {"ssm": (B,H,N,P), "conv": (B, Kc-1, conv_dim)}


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int, dtype=jnp.float32) -> SSMState:
    h, n, p = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((n_layers, batch, h, n, p), dtype),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssm_decode(
    params,
    x: jnp.ndarray,  # (B, 1, d)
    state: Dict[str, jnp.ndarray],  # per-layer slice {"ssm": (B,H,N,P), "conv": (B,Kc-1,C)}
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token Mamba2 step."""
    ct = cfg.cdtype()
    di, n, g, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(ct))[:, 0]
    z, xBC, dt = _split_proj(zxbcdt, cfg)

    conv_hist = jnp.concatenate([state["conv"].astype(ct), xBC[:, None, :]], axis=1)  # (B,Kc,C)
    w = params["conv_w"].astype(ct)  # (Kc, C)
    xBC = jax.nn.silu((conv_hist * w[None]).sum(axis=1) + params["conv_b"].astype(ct))
    new_conv = conv_hist[:, 1:]

    xs = xBC[..., :di].reshape(-1, h, p)
    Bm = xBC[..., di : di + g * n].reshape(-1, g, n)
    Cm = xBC[..., di + g * n :].reshape(-1, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_ssm = ssd_decode_step(
        state["ssm"].astype(jnp.float32), xs.astype(jnp.float32), dtv, A,
        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
    )
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = _gated_norm(y.reshape(-1, di).astype(ct), z, params["norm"])
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(ct))[:, None]
    return out, {"ssm": new_ssm.astype(state["ssm"].dtype), "conv": new_conv.astype(state["conv"].dtype)}
