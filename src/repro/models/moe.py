"""Top-k MoE with capacity-based gather dispatch under ``shard_map``.

The dispatch is deliberately framed like the paper's storage path: tokens are
"pages", experts are "providers", and the router plus capacity logic is the
provider manager — each token-assignment is placed into a bounded per-expert
slot buffer (load balancing + capacity), computed entirely shard-locally and
combined with one ``psum`` (no global synchronization, mirroring the paper's
single-serialization-point discipline).

Two layouts, chosen by divisibility of ``n_experts`` by the model-axis size:

* **EP** (``E % tp == 0``, e.g. qwen3 128e over 16): each model rank owns
  ``E/tp`` whole experts with full ``d_ff``.
* **expert-TP** (e.g. mixtral 8e over 16): every rank holds all experts with
  ``d_ff/tp`` columns.

Both keep the same local dispatch code; only the expert range / ffn slice
differ. Token→slot routing uses a *gather* formulation (scatter token indices,
then gather rows) so no ``(tokens, k, d)`` intermediate is ever materialized.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map

from repro.models.config import ModelConfig
from repro.models.modules import dense_init
from repro.parallel.axisinfo import AxisInfo


def moe_init(key, cfg: ModelConfig):
    kr, k1, kg, k2 = jax.random.split(key, 4)
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.pdtype()
    params = {
        "router": dense_init(kr, d, (E,), jnp.float32),  # router in fp32
        "w1": jax.vmap(lambda k: dense_init(k, d, (f,), dt))(jax.random.split(k1, E)),
        "wg": jax.vmap(lambda k: dense_init(k, d, (f,), dt))(jax.random.split(kg, E)),
        "w2": jax.vmap(lambda k: dense_init(k, f, (d,), dt))(jax.random.split(k2, E)),
    }
    axes = {
        "router": ("embed", "experts_router"),
        "w1": ("experts", "embed", "moe_ffn"),
        "wg": ("experts", "embed", "moe_ffn"),
        "w2": ("experts", "moe_ffn", "embed"),
    }
    return params, axes


def use_expert_parallel(cfg: ModelConfig, tp: int) -> bool:
    if cfg.n_experts % tp == 0:
        return True
    if cfg.d_ff % tp == 0:
        return False
    raise ValueError(f"neither experts ({cfg.n_experts}) nor d_ff ({cfg.d_ff}) divide tp={tp}")


def _moe_local(
    x: jnp.ndarray,  # (T, d) this shard's tokens
    router: jnp.ndarray,  # (d, E) full router
    w1: jnp.ndarray,  # (E_loc, d, f_loc)
    wg: jnp.ndarray,
    w2: jnp.ndarray,  # (E_loc, f_loc, d)
    cfg: ModelConfig,
    *,
    first_expert,  # first expert id owned by this rank (0 for expert-TP)
    n_local_experts: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shard-local dispatch → expert matmuls → combine. Returns (out, aux)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ct = cfg.cdtype()

    gates = jnp.einsum("td,de->te", x.astype(jnp.float32), router)  # (T, E)
    probs = jax.nn.softmax(gates, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, k)  # (T, k)
    top_w = jax.nn.softmax(top_w, axis=-1)  # renormalize over selected

    # auxiliary load-balance loss (Switch-style): E * Σ_e f_e · p_e
    counts = jnp.zeros((E,)).at[top_e.reshape(-1)].add(1.0)
    frac = counts / (T * k)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    # capacity per expert, over this shard's token-assignments
    C = max(int(T * k / E * cfg.capacity_factor), 4)

    flat_e = top_e.reshape(-1)  # (T*k,) expert of each assignment
    # position of each assignment within its expert, via stable sort ranking
    # (avoids a (T·k, E) one-hot cumsum intermediate)
    idx_sorted = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[idx_sorted]
    start = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(flat_e.size, dtype=jnp.int32) - start[e_sorted].astype(jnp.int32)
    pos_in_e = jnp.zeros((flat_e.size,), jnp.int32).at[idx_sorted].set(rank_sorted)

    local_e = flat_e - first_expert
    keep = (pos_in_e < C) & (local_e >= 0) & (local_e < n_local_experts)
    slot = jnp.where(keep, local_e * C + pos_in_e, n_local_experts * C)  # OOB => dropped

    # gather-style dispatch: slot -> source token index
    token_idx = jnp.arange(T * k, dtype=jnp.int32) // k
    slot_token = jnp.full((n_local_experts * C,), T, jnp.int32).at[slot].set(token_idx, mode="drop")
    slot_valid = slot_token < T
    xg = jnp.where(slot_valid[:, None], x[jnp.clip(slot_token, 0, T - 1)], 0.0)
    disp = xg.reshape(n_local_experts, C, d).astype(ct)

    h = jnp.einsum("ecd,edf->ecf", disp, w1.astype(ct))
    g = jnp.einsum("ecd,edf->ecf", disp, wg.astype(ct))
    h = jax.nn.silu(g) * h
    out_slots = jnp.einsum("ecf,efd->ecd", h, w2.astype(ct)).reshape(n_local_experts * C, d)

    # combine: scatter expert outputs back to tokens, weighted by gate prob
    slot_w = jnp.zeros((n_local_experts * C,), jnp.float32).at[slot].set(
        top_w.reshape(-1), mode="drop"
    )
    out = (
        jnp.zeros((T, d), jnp.float32)
        .at[jnp.clip(slot_token, 0, T - 1)]
        .add(out_slots.astype(jnp.float32) * slot_w[:, None] * slot_valid[:, None], mode="drop")
    )
    return out.astype(x.dtype), aux


def moe_ffn(
    params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    axis_info: Optional[AxisInfo],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE feed-forward. Returns (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape

    if axis_info is None:
        out, aux = _moe_local(
            x.reshape(B * S, d), params["router"], params["w1"], params["wg"], params["w2"],
            cfg, first_expert=0, n_local_experts=cfg.n_experts,
        )
        return out.reshape(B, S, d), aux

    mesh = axis_info.mesh
    tp = mesh.shape[axis_info.model_axis]
    ep = use_expert_parallel(cfg, tp)
    n_local = cfg.n_experts // tp if ep else cfg.n_experts
    batch_axes = axis_info.batch_axes
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if (B * S) % n_batch:
        batch_axes = ()  # tiny decode batches: replicate tokens, keep EP/TP
    ma = axis_info.model_axis
    w_spec = P(ma, None, None) if ep else P(None, None, ma)
    w2_spec = P(ma, None, None) if ep else P(None, ma, None)

    def local_fn(xf, router, w1, wg, w2):
        first = jax.lax.axis_index(ma) * n_local if ep else 0
        out, aux = _moe_local(
            xf, router, w1, wg, w2, cfg, first_expert=first, n_local_experts=n_local
        )
        out = jax.lax.psum(out, ma)
        # aux is identical across ma ranks (computed from replicated gates);
        # average over the batch shards only.
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    xf = x.reshape(B * S, d)
    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(batch_axes, None), P(None, None), w_spec, w_spec, w2_spec),
        out_specs=(P(batch_axes, None), P()),
        check_vma=False,
    )(xf, params["router"], params["w1"], params["wg"], params["w2"])
    return out.reshape(B, S, d), aux
