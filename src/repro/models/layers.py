"""Shared layers: RMSNorm, RoPE, gated MLP, embeddings."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import dense_init, embed_init


# -- RMSNorm ---------------------------------------------------------------------
def rmsnorm_init(cfg: ModelConfig, dim: int = 0):
    dim = dim or cfg.d_model
    return jnp.ones((dim,), cfg.pdtype()), ("embed",)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with a dtype-disciplined custom VJP.

    Statistics accumulate in f32, but every FULL tensor (forward output,
    saved residual, backward products) stays in ``x.dtype``. Without this,
    autodiff's f32 cotangent of the variance forces an f32 copy of the
    residual stream — XLA then hoists that convert out of the backward layer
    loop, keeping an extra f32 copy of the whole remat stack live
    (2×L×S×d bytes; measured in EXPERIMENTS.md §Perf iteration 2).
    """
    return _rmsnorm_fwd(x, scale, eps)[0]


def _rmsnorm_fwd(x, scale, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps)  # (..., 1) f32 — per-token statistic only
    y = x * inv.astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, scale, inv)


def _rmsnorm_bwd(eps, res, g):
    x, scale, inv = res
    n = x.shape[-1]
    gs = g * scale.astype(g.dtype)  # stays in activation dtype
    s = jnp.sum(gs * x, axis=-1, keepdims=True, dtype=jnp.float32)
    coef = (s * inv**3 / n).astype(x.dtype)
    dx = gs * inv.astype(x.dtype) - x * coef
    dscale = jnp.sum(
        (g * x).astype(jnp.float32) * inv, axis=tuple(range(x.ndim - 1))
    )
    return dx, dscale.astype(scale.dtype)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


# -- RoPE ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- gated MLP (SiLU) -------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.pdtype()
    params = {
        "wi": dense_init(k1, d, (f,), dt),
        "wg": dense_init(k2, d, (f,), dt),
        "wo": dense_init(k3, f, (d,), dt),
    }
    axes = {"wi": ("embed", "ffn"), "wg": ("embed", "ffn"), "wo": ("ffn", "embed")}
    return params, axes


def mlp(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    ct = cfg.cdtype()
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(ct))
    g = jnp.einsum("...d,df->...f", x, params["wg"].astype(ct))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(ct))


# -- embeddings --------------------------------------------------------------------------
def embedding_init(key, cfg: ModelConfig):
    params = {"table": embed_init(key, cfg.padded_vocab, cfg.d_model, cfg.pdtype())}
    axes = {"table": ("vocab", "embed_table")}
    return params, axes


def embed(params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return params["table"].astype(cfg.cdtype())[tokens]


def unembed(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Tied unembedding → logits over the padded vocab (float32)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), params["table"].astype(jnp.float32)
    )


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over non-padding labels (label == -1 is padding). Padded vocab
    tail is masked out. Returns (loss, accuracy).

    The gold logit is extracted with a one-hot contraction rather than
    ``take_along_axis`` so the vocab axis can stay model-sharded under GSPMD
    (a gather along a sharded axis forces an all-gather of the logits).
    """
    mask = labels >= 0
    labels = jnp.where(mask, labels, 0)
    vmask = jnp.arange(logits.shape[-1]) < vocab_size
    logits = jnp.where(vmask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return nll.sum() / denom, acc
