"""Top-level models, one builder per family.

Every family exposes the same functional API:

* ``init(key, cfg) -> (params, axes)``
* ``train_loss(params, batch, cfg, axis_info) -> (loss, metrics)``
* ``prefill(params, batch, cfg, axis_info) -> (logits, cache)``  — cache is a
  pytree holding paged KV pools / SSM states + ``lengths``
* ``decode_step(params, cache, tokens, cfg, axis_info) -> (logits, cache)``

Batches are dicts: ``tokens``/``labels`` for LMs, ``embeds`` for backbone-only
VLM/audio stubs, ``enc_embeds``+``tokens`` for enc-dec.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.models import attention as attn
from repro.models import blocks as B
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy, embed, embedding_init, rmsnorm, rmsnorm_init, unembed
from repro.models.modules import validate_trees
from repro.parallel.axisinfo import AxisInfo, constrain_batch

MOE_AUX_WEIGHT = 0.01




def _pool_cache(cfg, pool_k, pool_v, tables, page_pos):
    """Assemble a paged-cache dict, quantizing pools to int8 (per-token
    scales) when the config asks for it."""
    dt = jnp.dtype(cfg.kv_cache_dtype)
    if dt == jnp.int8:
        qk, sk = ops.quantize_token(pool_k)
        qv, sv = ops.quantize_token(pool_v)
        return {"pool_k": qk, "pool_v": qv, "scale_k": sk, "scale_v": sv,
                "tables": tables, "page_pos": page_pos}
    return {"pool_k": pool_k.astype(dt), "pool_v": pool_v.astype(dt),
            "tables": tables, "page_pos": page_pos}


def _pages_extra(S: int, B: int, cfg, axis_info) -> int:
    """Decode-headroom pages per sequence appended at prefill.

    Single-device (engine/tests): one page so decode can append immediately.
    Distributed: ZERO — any padding makes the pool a concat of a reshape,
    which is not block-compatible with the page striping and forces GSPMD to
    replicate the whole K/V stack; the serving engine owns decode headroom
    through its page allocator instead (the provider manager's job).
    """
    return 0 if axis_info is not None else 1


def _constrain_logits(logits, axis_info):
    """(B, S, V): batch over DP axes, vocab over the model axis."""
    if axis_info is None:
        return logits
    import jax as _jax
    from jax.sharding import NamedSharding as _NS, PartitionSpec as _P

    n = 1
    for a in axis_info.batch_axes:
        n *= axis_info.mesh.shape[a]
    tp = axis_info.mesh.shape[axis_info.model_axis]
    spec = [None] * logits.ndim
    if logits.shape[0] % n == 0:
        spec[0] = axis_info.batch_axes
    if logits.shape[-1] % tp == 0:
        spec[-1] = axis_info.model_axis
    return _jax.lax.with_sharding_constraint(logits, _NS(axis_info.mesh, _P(*spec)))


@dataclasses.dataclass(frozen=True)
class Model:
    init: Any
    train_loss: Any
    prefill: Any
    decode_step: Any
    init_cache: Any  # (cfg, batch, seq_len, pad_pages_to) -> cache pytree


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _decoder_lm(cfg)
    if fam == "ssm":
        return _ssm_lm(cfg)
    if fam == "hybrid":
        return _hybrid_lm(cfg)
    if fam in ("encdec", "audio"):
        return _encdec_lm(cfg)
    raise ValueError(f"unknown family {fam}")


def _inputs_to_h(params, batch, cfg, axis_info=None):
    if "embeds" in batch:
        h = batch["embeds"].astype(cfg.cdtype())
    else:
        h = embed(params["embed"], batch["tokens"], cfg)
    return constrain_batch(h, axis_info)


# ================================ decoder-only ================================
def _decoder_lm(cfg: ModelConfig) -> Model:
    def init(key):
        ke, kb = jax.random.split(key)
        e_params, e_axes = embedding_init(ke, cfg)
        b_params, b_axes = B.stack_init(kb, cfg.n_layers, lambda k: B.block_init(k, cfg))
        lnf, lnf_ax = rmsnorm_init(cfg)
        params = {"embed": e_params, "blocks": b_params, "ln_f": lnf}
        axes = {"embed": e_axes, "blocks": b_axes, "ln_f": lnf_ax}
        validate_trees(params, axes)
        return params, axes

    def backbone(params, h, axis_info, collect_kv=False):
        if collect_kv:
            body = lambda p, x: B.block_apply(p, x, cfg, axis_info, return_kv=True)
            h, aux, kvs = B.scan_apply_collect_kv(params["blocks"], h, body, cfg, axis_info)
            return rmsnorm(h, params["ln_f"]), aux, kvs
        body = lambda p, x: B.block_apply(p, x, cfg, axis_info)
        h, aux = B.scan_apply(params["blocks"], h, body, cfg, axis_info)
        return rmsnorm(h, params["ln_f"]), aux

    def train_loss(params, batch, axis_info):
        h = _inputs_to_h(params, batch, cfg, axis_info)
        h, aux = backbone(params, h, axis_info)
        logits = _constrain_logits(unembed(params["embed"], h, cfg), axis_info)
        ce, acc = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        loss = ce + MOE_AUX_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux, "acc": acc}

    def init_cache(batch, seq_len, pad_pages_to=1):
        cache, lengths = attn.init_decode_cache(
            cfg, batch, seq_len, cfg.n_layers, pad_pages_to=pad_pages_to
        )
        return {"kv": cache, "lengths": lengths}

    def prefill(params, batch, axis_info):
        h = _inputs_to_h(params, batch, cfg, axis_info)
        Bb, S = h.shape[:2]
        h, _, kvs = backbone(params, h, axis_info, collect_kv=True)
        logits = unembed(params["embed"], h[:, -1:], cfg)[:, 0]
        k, v = kvs  # (L, B, S, K, hd)
        extra = _pages_extra(S, Bb, cfg, axis_info)
        pool_k, pool_v, tables, page_pos = jax.vmap(
            lambda kk, vv: ops.prefill_into_pages(kk, vv, cfg.kv_page_tokens, extra_pages=extra)
        )(k, v)
        cache = {
            "kv": _pool_cache(cfg, pool_k, pool_v, tables, page_pos),
            "lengths": jnp.full((Bb,), S, jnp.int32),
        }
        return logits, cache

    def decode_step(params, cache, tokens, axis_info):
        h = embed(params["embed"], tokens[:, None], cfg)
        lengths = cache["lengths"]

        def body(p, x, c):
            return B.block_decode(p, x, c, lengths, cfg, axis_info)

        h, kv = B.scan_decode(params["blocks"], h, cache["kv"], body)
        h = rmsnorm(h, params["ln_f"])
        logits = unembed(params["embed"], h, cfg)[:, 0]
        return logits, {"kv": kv, "lengths": lengths + 1}

    return Model(init, train_loss, prefill, decode_step, init_cache)


# ================================ pure SSM (mamba2) ================================
def _ssm_lm(cfg: ModelConfig) -> Model:
    def init(key):
        ke, kb = jax.random.split(key)
        e_params, e_axes = embedding_init(ke, cfg)
        b_params, b_axes = B.stack_init(kb, cfg.n_layers, lambda k: B.ssm_block_init(k, cfg))
        lnf, lnf_ax = rmsnorm_init(cfg)
        params = {"embed": e_params, "blocks": b_params, "ln_f": lnf}
        axes = {"embed": e_axes, "blocks": b_axes, "ln_f": lnf_ax}
        validate_trees(params, axes)
        return params, axes

    def train_loss(params, batch, axis_info):
        h = _inputs_to_h(params, batch, cfg, axis_info)
        body = lambda p, x: (B.ssm_block_apply(p, x, cfg), jnp.zeros((), jnp.float32))
        h, _ = B.scan_apply(params["blocks"], h, body, cfg, axis_info)
        h = rmsnorm(h, params["ln_f"])
        logits = _constrain_logits(unembed(params["embed"], h, cfg), axis_info)
        ce, acc = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return ce, {"ce": ce, "acc": acc}

    def init_cache(batch, seq_len, pad_pages_to=1):
        return {
            "ssm": ssm_mod.init_ssm_state(cfg, batch, cfg.n_layers),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(params, batch, axis_info):
        h = _inputs_to_h(params, batch, cfg, axis_info)
        Bb, S = h.shape[:2]

        # run blocks sequentially collecting final states (prefill = train fwd
        # + state handoff); python loop is fine: params are scanned instead.
        def body(carry, layer_params):
            x = carry
            hh = rmsnorm(x, layer_params["ln"])
            ct = cfg.cdtype()
            # replicate ssm_forward but returning final state
            y, state = _ssm_forward_with_state(layer_params["ssm"], hh, cfg)
            return x + y, state

        h, states = lax.scan(body, h, params["blocks"])
        h = rmsnorm(h, params["ln_f"])
        logits = unembed(params["embed"], h[:, -1:], cfg)[:, 0]
        cache = {"ssm": states, "lengths": jnp.full((Bb,), S, jnp.int32)}
        return logits, cache

    def decode_step(params, cache, tokens, axis_info):
        h = embed(params["embed"], tokens[:, None], cfg)

        def body(x, inp):
            layer_params, state = inp
            x, new_state = B.ssm_block_decode(layer_params, x, state, cfg)
            return x, new_state

        h, states = lax.scan(body, h, (params["blocks"], cache["ssm"]))
        h = rmsnorm(h, params["ln_f"])
        logits = unembed(params["embed"], h, cfg)[:, 0]
        return logits, {"ssm": states, "lengths": cache["lengths"] + 1}

    return Model(init, train_loss, prefill, decode_step, init_cache)


def _ssm_forward_with_state(params, x, cfg: ModelConfig):
    """ssm_forward variant that also returns the final recurrent state +
    conv tail (for prefill→decode handoff)."""
    ct = cfg.cdtype()
    di, n, g, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(ct))
    z, xBC_pre, dt = ssm_mod._split_proj(zxbcdt, cfg)
    conv_tail = xBC_pre[:, -(cfg.ssm_conv - 1):, :]
    xBC = jax.nn.silu(
        ssm_mod._causal_conv(xBC_pre, params["conv_w"].astype(ct), params["conv_b"].astype(ct))
    )
    xs = xBC[..., :di].reshape(*xBC.shape[:2], h, p)
    Bm = xBC[..., di : di + g * n].reshape(*xBC.shape[:2], g, n)
    Cm = xBC[..., di + g * n :].reshape(*xBC.shape[:2], g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, final = ssm_mod.ssd_chunked(xs, dtv, A, Bm, Cm, cfg.ssm_chunk, return_state=True)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = ssm_mod._gated_norm(y.reshape(*x.shape[:2], di).astype(ct), z, params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(ct))
    state = {"ssm": final.astype(jnp.float32), "conv": conv_tail.astype(jnp.float32)}
    return out, state


# ================================ hybrid (zamba2) ================================
def _hybrid_lm(cfg: ModelConfig) -> Model:
    n_groups = cfg.n_layers // cfg.attn_every
    per_group = cfg.attn_every

    def init(key):
        ke, km, ka = jax.random.split(key, 3)
        e_params, e_axes = embedding_init(ke, cfg)
        m_params, m_axes = B.stack_init(km, cfg.n_layers, lambda k: B.ssm_block_init(k, cfg))
        # reshape mamba stack to (n_groups, per_group, ...)
        m_params = jax.tree.map(lambda x: x.reshape(n_groups, per_group, *x.shape[1:]), m_params)
        m_axes = jax.tree.map(
            lambda a: ("groups",) + tuple(a), m_axes, is_leaf=lambda x: isinstance(x, tuple)
        )
        a_params, a_axes = B.block_init(ka, cfg)  # ONE shared attention block
        lnf, lnf_ax = rmsnorm_init(cfg)
        params = {"embed": e_params, "mamba": m_params, "shared_attn": a_params, "ln_f": lnf}
        axes = {"embed": e_axes, "mamba": m_axes, "shared_attn": a_axes, "ln_f": lnf_ax}
        validate_trees(params, axes)
        return params, axes

    def train_loss(params, batch, axis_info):
        h = _inputs_to_h(params, batch, cfg, axis_info)
        shared = params["shared_attn"]

        def group_body(carry, group_params):
            x, _ = carry
            x, _aux = B.scan_apply(
                group_params, x,
                lambda p, xx: (B.ssm_block_apply(p, xx, cfg), jnp.zeros((), jnp.float32)),
                cfg, axis_info,
            )
            x, aux = B.checkpoint_wrap(
                lambda p, xx: B.block_apply(p, xx, cfg, axis_info), cfg
            )(shared, x)
            return (constrain_batch(x, axis_info), aux), None

        (h, _), _ = lax.scan(group_body, (h, jnp.zeros((), jnp.float32)), params["mamba"])
        h = rmsnorm(h, params["ln_f"])
        logits = _constrain_logits(unembed(params["embed"], h, cfg), axis_info)
        ce, acc = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return ce, {"ce": ce, "acc": acc}

    def init_cache(batch, seq_len, pad_pages_to=1):
        kv, lengths = attn.init_decode_cache(cfg, batch, seq_len, n_groups, pad_pages_to=pad_pages_to)
        return {
            "ssm": ssm_mod.init_ssm_state(cfg, batch, cfg.n_layers),
            "kv": kv,
            "lengths": lengths,
        }

    def prefill(params, batch, axis_info):
        h = _inputs_to_h(params, batch, cfg, axis_info)
        Bb, S = h.shape[:2]
        shared = params["shared_attn"]

        def group_body(x, group_params):
            def mamba_body(xx, lp):
                hh = rmsnorm(xx, lp["ln"])
                y, st = _ssm_forward_with_state(lp["ssm"], hh, cfg)
                return xx + y, st

            x, states = lax.scan(mamba_body, x, group_params)
            x, _, kv = B.block_apply(shared, x, cfg, axis_info, return_kv=True)
            return x, (states, kv)

        h, (states, kvs) = lax.scan(group_body, h, params["mamba"])
        # states: {"ssm": (G, pg, B, ...)} → flatten to (L, B, ...)
        states = jax.tree.map(lambda s: s.reshape(cfg.n_layers, *s.shape[2:]), states)
        k, v = kvs  # (G, B, S, K, hd)
        extra = _pages_extra(S, Bb, cfg, axis_info)
        pool_k, pool_v, tables, page_pos = jax.vmap(
            lambda kk, vv: ops.prefill_into_pages(kk, vv, cfg.kv_page_tokens, extra_pages=extra)
        )(k, v)
        h = rmsnorm(h, params["ln_f"])
        logits = unembed(params["embed"], h[:, -1:], cfg)[:, 0]
        cache = {
            "ssm": states,
            "kv": _pool_cache(cfg, pool_k, pool_v, tables, page_pos),
            "lengths": jnp.full((Bb,), S, jnp.int32),
        }
        return logits, cache

    def decode_step(params, cache, tokens, axis_info):
        h = embed(params["embed"], tokens[:, None], cfg)
        lengths = cache["lengths"]
        shared = params["shared_attn"]
        ssm_states = jax.tree.map(
            lambda s: s.reshape(n_groups, per_group, *s.shape[1:]), cache["ssm"]
        )

        def group_body(x, inp):
            group_params, group_state, kv_slice = inp

            def mamba_body(xx, lp_state):
                lp, st = lp_state
                xx, new_st = B.ssm_block_decode(lp, xx, st, cfg)
                return xx, new_st

            x, new_states = lax.scan(mamba_body, x, (group_params, group_state))
            x, new_kv = B.block_decode(shared, x, kv_slice, lengths, cfg, axis_info)
            return x, (new_states, new_kv)

        h, (new_states, new_kv) = lax.scan(group_body, h, (params["mamba"], ssm_states, cache["kv"]))
        new_states = jax.tree.map(lambda s: s.reshape(cfg.n_layers, *s.shape[2:]), new_states)
        h = rmsnorm(h, params["ln_f"])
        logits = unembed(params["embed"], h, cfg)[:, 0]
        return logits, {"ssm": new_states, "kv": new_kv, "lengths": lengths + 1}

    return Model(init, train_loss, prefill, decode_step, init_cache)


# ================================ encoder-decoder ================================
def _encdec_lm(cfg: ModelConfig) -> Model:
    n_enc, n_dec = cfg.n_enc_layers, cfg.n_dec_layers

    def dec_block_init(key):
        ka, kc, km = jax.random.split(key, 3)
        a_params, a_axes = attn.attention_init(ka, cfg)
        c_params, c_axes = attn.attention_init(kc, cfg)
        from repro.models.layers import mlp_init

        m_params, m_axes = mlp_init(km, cfg)
        ln1, lax1 = rmsnorm_init(cfg)
        ln2, lax2 = rmsnorm_init(cfg)
        ln3, lax3 = rmsnorm_init(cfg)
        params = {"ln1": ln1, "self": a_params, "ln2": ln2, "cross": c_params, "ln3": ln3, "mlp": m_params}
        axes = {"ln1": lax1, "self": a_axes, "ln2": lax2, "cross": c_axes, "ln3": lax3, "mlp": m_axes}
        return params, axes

    def init(key):
        ke, kenc, kdec = jax.random.split(key, 3)
        e_params, e_axes = embedding_init(ke, cfg)
        enc_params, enc_axes = B.stack_init(kenc, n_enc, lambda k: B.block_init(k, cfg))
        dec_params, dec_axes = B.stack_init(kdec, n_dec, dec_block_init)
        ln_e, lax_e = rmsnorm_init(cfg)
        ln_d, lax_d = rmsnorm_init(cfg)
        params = {"embed": e_params, "encoder": enc_params, "decoder": dec_params,
                  "ln_enc": ln_e, "ln_dec": ln_d}
        axes = {"embed": e_axes, "encoder": enc_axes, "decoder": dec_axes,
                "ln_enc": lax_e, "ln_dec": lax_d}
        validate_trees(params, axes)
        return params, axes

    def encode(params, enc_embeds, axis_info):
        h = enc_embeds.astype(cfg.cdtype())
        body = lambda p, x: B.block_apply(p, x, cfg, axis_info, causal=False)
        h, _ = B.scan_apply(params["encoder"], h, body, cfg, axis_info)
        return rmsnorm(h, params["ln_enc"])

    def dec_block_apply(p, x, enc_out, axis_info):
        h = rmsnorm(x, p["ln1"])
        x = x + attn.attention_train(p["self"], h, cfg, causal=True)
        h = rmsnorm(x, p["ln2"])
        x = x + attn.attention_train(p["cross"], h, cfg, kv_src=enc_out)
        h = rmsnorm(x, p["ln3"])
        from repro.models.layers import mlp

        return x + mlp(p["mlp"], h, cfg)

    def train_loss(params, batch, axis_info):
        enc_out = encode(params, batch["enc_embeds"], axis_info)
        h = embed(params["embed"], batch["tokens"], cfg)

        def body(carry, p):
            x, aux = carry
            x = B.checkpoint_wrap(lambda pp, xx: dec_block_apply(pp, xx, enc_out, axis_info), cfg)(p, x)
            return (constrain_batch(x, axis_info), aux), None

        (h, _), _ = lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["decoder"])
        h = rmsnorm(h, params["ln_dec"])
        logits = _constrain_logits(unembed(params["embed"], h, cfg), axis_info)
        ce, acc = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return ce, {"ce": ce, "acc": acc}

    def init_cache(batch, seq_len, pad_pages_to=1):
        self_kv, lengths = attn.init_decode_cache(cfg, batch, seq_len, n_dec, pad_pages_to=pad_pages_to)
        cross_kv, _ = attn.init_decode_cache(
            cfg, batch, seq_len, n_dec, dtype=jnp.dtype(cfg.kv_cache_dtype), pad_pages_to=pad_pages_to
        )
        return {"self_kv": self_kv, "cross_kv": cross_kv, "lengths": lengths,
                "enc_len": jnp.zeros((batch,), jnp.int32)}

    def prefill(params, batch, axis_info):
        """Encode source; build cross-attn pools; decoder cache starts empty
        (or pref'd from ``batch['tokens']`` if provided)."""
        enc_out = encode(params, batch["enc_embeds"], axis_info)
        Bb, S_enc = enc_out.shape[:2]
        ct = cfg.cdtype()

        def cross_kv_one(p):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(ct))
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(ct))
            return k, v

        k, v = jax.vmap(cross_kv_one)(params["decoder"])  # (L_dec, B, S, K, hd)
        extra = _pages_extra(S_enc, Bb, cfg, axis_info)
        pool_k, pool_v, tables, page_pos = jax.vmap(
            lambda kk, vv: ops.prefill_into_pages(kk, vv, cfg.kv_page_tokens, extra_pages=extra)
        )(k, v)
        cross_kv = _pool_cache(cfg, pool_k, pool_v, tables, page_pos)

        dec_tokens = batch.get("tokens")
        if dec_tokens is not None:
            S_dec = dec_tokens.shape[1]
            h = embed(params["embed"], dec_tokens, cfg)

            def body(carry, p):
                x = carry
                hh = rmsnorm(x, p["ln1"])
                a, kv = attn.attention_train(p["self"], hh, cfg, causal=True, return_kv=True, axis_info=axis_info)
                x = x + a
                hh = rmsnorm(x, p["ln2"])
                x = x + attn.attention_train(p["cross"], hh, cfg, kv_src=enc_out)
                hh = rmsnorm(x, p["ln3"])
                from repro.models.layers import mlp

                return x + mlp(p["mlp"], hh, cfg), kv

            h, kvs = lax.scan(body, h, params["decoder"])
            sk, sv = kvs
            sextra = _pages_extra(S_dec, Bb, cfg, axis_info)
            spool_k, spool_v, stables, spage_pos = jax.vmap(
                lambda kk, vv: ops.prefill_into_pages(kk, vv, cfg.kv_page_tokens, extra_pages=sextra)
            )(sk, sv)
            self_kv = _pool_cache(cfg, spool_k, spool_v, stables, spage_pos)
            h = rmsnorm(h, params["ln_dec"])
            logits = unembed(params["embed"], h[:, -1:], cfg)[:, 0]
            lengths = jnp.full((Bb,), S_dec, jnp.int32)
        else:
            self_kv, lengths = attn.init_decode_cache(cfg, Bb, S_enc, n_dec)
            logits = jnp.zeros((Bb, cfg.padded_vocab), jnp.float32)
        cache = {"self_kv": self_kv, "cross_kv": cross_kv, "lengths": lengths,
                 "enc_len": jnp.full((Bb,), S_enc, jnp.int32)}
        return logits, cache

    def decode_step(params, cache, tokens, axis_info):
        h = embed(params["embed"], tokens[:, None], cfg)
        lengths = cache["lengths"]
        enc_len = cache["enc_len"]

        def body(x, inp):
            p, self_c, cross_c = inp
            hh = rmsnorm(x, p["ln1"])
            a, self_c = attn.attention_decode(p["self"], hh, self_c, lengths, cfg, axis_info)
            x = x + a
            hh = rmsnorm(x, p["ln2"])
            a, _ = attn.attention_decode(
                p["cross"], hh, cross_c, enc_len, cfg, axis_info, update=False, rope=False
            )
            x = x + a
            hh = rmsnorm(x, p["ln3"])
            from repro.models.layers import mlp

            x = x + mlp(p["mlp"], hh, cfg)
            return x, self_c

        h, self_kv = lax.scan(
            lambda x, inp: body(x, inp), h, (params["decoder"], cache["self_kv"], cache["cross_kv"])
        )
        h = rmsnorm(h, params["ln_dec"])
        logits = unembed(params["embed"], h, cfg)[:, 0]
        return logits, dict(cache, self_kv=self_kv, lengths=lengths + 1)

    return Model(init, train_loss, prefill, decode_step, init_cache)
