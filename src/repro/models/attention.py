"""GQA attention: training/prefill (flash-chunked) + paged decode paths.

Decode reads/writes the paged KV pool through ``shard_map``: the pool is
sharded over every mesh axis (the paper's page striping), each shard computes
partial online-softmax stats over the pages it owns, and partials are combined
with collectives — flash-decoding as "concurrent fine-grain reads of a striped
blob".
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope
from repro.models.modules import dense_init
from repro.parallel.axisinfo import AxisInfo, constrain_batch, page_offset_in_shard



def _constrain_kv(x, axis_info: Optional[AxisInfo]):
    """Cache-bound K/V (B, S, K, hd): batch over DP axes, seq over the model
    axis — pre-aligns the layout with the page-pool striping so the
    prefill->pool reshard is local."""
    if axis_info is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = axis_info.mesh
    n = 1
    for a in axis_info.batch_axes:
        n *= mesh.shape[a]
    tp = mesh.shape[axis_info.model_axis]
    spec = [None, None, None, None]
    if x.shape[0] % n == 0:
        spec[0] = axis_info.batch_axes
    if x.shape[1] % tp == 0:
        spec[1] = axis_info.model_axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def attention_init(key, cfg: ModelConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, H, K, hd, dt = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.pdtype()
    params = {
        "wq": dense_init(kq, d, (H, hd), dt),
        "wk": dense_init(kk, d, (K, hd), dt),
        "wv": dense_init(kv, d, (K, hd), dt),
        "wo": dense_init(ko, H * hd, (d,), dt).reshape(H, hd, d),
    }
    axes = {
        "wq": ("embed", "q_heads", "head"),
        "wk": ("embed", "kv_heads", "head"),
        "wv": ("embed", "kv_heads", "head"),
        "wo": ("q_heads", "head", "embed"),
    }
    return params, axes


def qkv(params, x: jnp.ndarray, cfg: ModelConfig, positions: Optional[jnp.ndarray], rope: bool = True):
    """Project + rotate. x: (B, S, d) → q (B,S,H,hd), k/v (B,S,K,hd)."""
    ct = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(ct))
    if rope:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(params, o: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return jnp.einsum("...hk,hkd->...d", o, params["wo"].astype(cfg.cdtype()))


def attention_train(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    kv_src: Optional[jnp.ndarray] = None,
    rope: bool = True,
    return_kv: bool = False,
    axis_info: Optional[AxisInfo] = None,
):
    """Full-sequence attention (training / prefill / encoder / cross).

    ``kv_src`` switches to cross-attention (keys/values from another
    sequence, no RoPE, non-causal).
    """
    if kv_src is not None:
        ct = cfg.cdtype()
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(ct))
        k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"].astype(ct))
        v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"].astype(ct))
        causal, rope = False, False
    else:
        q, k, v = qkv(params, x, cfg, None, rope=rope)
    kv_cache = None
    if return_kv:
        # the CACHE copy gets the pool-aligned (batch, seq->model) layout.
        # The optimization barrier stops GSPMD from back-propagating the
        # seq-sharding through the QKV einsum into the residual stream
        # (measured without it: 48 GB/dev of f32 residual all-gathers on
        # danube prefill); the reshard then happens exactly once, on the
        # K/V tensors themselves.
        kb, vb = jax.lax.optimization_barrier((k, v))
        kv_cache = (_constrain_kv(kb, axis_info), _constrain_kv(vb, axis_info))
    o = ops.flash_attention(
        q, k, v,
        causal=causal,
        window=cfg.sliding_window if causal else None,
        q_chunk=cfg.attn_chunk,
        impl="pallas" if cfg.use_pallas else "xla",
    )
    out = out_proj(params, o, cfg)
    if return_kv:
        return out, kv_cache
    return out


# ------------------------------- paged decode --------------------------------

CacheLayer = Dict[str, jnp.ndarray]  # pool_k, pool_v, tables, page_pos


def decode_cache_specs(axis_info: Optional[AxisInfo]):
    """(in-)shardings of one cache layer pytree."""
    if axis_info is None:
        return {k: P() for k in ("pool_k", "pool_v", "tables", "page_pos")}
    return {
        "pool_k": P(axis_info.page_axes),
        "pool_v": P(axis_info.page_axes),
        "tables": P(),
        "page_pos": P(),
    }


def attention_decode(
    params,
    x: jnp.ndarray,  # (B, 1, d)
    cache: CacheLayer,
    lengths: jnp.ndarray,  # (B,) tokens already cached (new token position)
    cfg: ModelConfig,
    axis_info: Optional[AxisInfo],
    *,
    update: bool = True,
    rope: bool = True,
) -> Tuple[jnp.ndarray, CacheLayer]:
    """One decode step: append this token's K/V (paper WRITE), then attend over
    the paged pool (paper READ). ``update=False`` gives read-only attention
    (cross-attention over a prefilled pool)."""
    q, k, v = qkv(params, x, cfg, lengths[:, None] if rope else None, rope=rope)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]  # (B, H/K, hd)

    impl = "pallas" if cfg.use_pallas else "xla"
    window = cfg.sliding_window

    quant = cache["pool_k"].dtype == jnp.int8
    if axis_info is None:
        pool_k, pool_v, page_pos = cache["pool_k"], cache["pool_v"], cache["page_pos"]
        sk, sv = cache.get("scale_k"), cache.get("scale_v")
        if update:
            out = ops.paged_update(
                pool_k, pool_v, cache["tables"], page_pos, lengths, k1, v1,
                scale_k=sk, scale_v=sv,
            )
            if quant:
                pool_k, pool_v, page_pos, sk, sv = out
            else:
                pool_k, pool_v, page_pos = out
        o = ops.paged_attention(
            q1, pool_k, pool_v, cache["tables"], page_pos,
            lengths + (1 if update else 0), scale_k=sk, scale_v=sv,
            window=window, impl=impl,
        )
        new_cache = dict(cache, pool_k=pool_k, pool_v=pool_v, page_pos=page_pos)
        if quant:
            new_cache.update(scale_k=sk, scale_v=sv)
        return out_proj(params, o[:, None], cfg), new_cache

    mesh = axis_info.mesh
    page_axes = axis_info.page_axes
    rep = P()  # replicated within shard_map

    sk = cache.get("scale_k") if quant else jnp.zeros((), jnp.float32)
    sv = cache.get("scale_v") if quant else jnp.zeros((), jnp.float32)

    def local(q1, k1, v1, pool_k, pool_v, sk, sv, tables, page_pos, lengths):
        offset = page_offset_in_shard(page_axes, pool_k.shape[0])
        if not quant:
            sk = sv = None
        if update:
            out = ops.paged_update(
                pool_k, pool_v, tables, page_pos, lengths, k1, v1,
                scale_k=sk, scale_v=sv, page_offset=offset,
            )
            if quant:
                pool_k, pool_v, page_pos_new, sk, sv = out
            else:
                pool_k, pool_v, page_pos_new = out
        else:
            page_pos_new = page_pos
        o = ops.paged_attention(
            q1, pool_k, pool_v, tables, page_pos_new,
            lengths + (1 if update else 0), scale_k=sk, scale_v=sv, window=window,
            page_offset=offset, axis_names=page_axes, impl=impl,
        )
        if not quant:
            sk = sv = jnp.zeros((), jnp.float32)
        # page_pos is replicated: every shard computes the same update
        return o, pool_k, pool_v, sk, sv, page_pos_new

    pool_spec = P(page_axes)
    scale_spec = pool_spec if quant else P()
    o, pool_k, pool_v, sk, sv, page_pos = shard_map(
        local,
        mesh=mesh,
        in_specs=(rep, rep, rep, pool_spec, pool_spec, scale_spec, scale_spec, rep, rep, rep),
        out_specs=(rep, pool_spec, pool_spec, scale_spec, scale_spec, rep),
        check_vma=False,
    )(q1, k1, v1, cache["pool_k"], cache["pool_v"], sk, sv,
      cache["tables"], cache["page_pos"], lengths)
    new_cache = dict(cache, pool_k=pool_k, pool_v=pool_v, page_pos=page_pos)
    if quant:
        new_cache.update(scale_k=sk, scale_v=sv)
    return out_proj(params, o[:, None], cfg), new_cache


def init_decode_cache(
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    n_layers: int,
    dtype=None,
    pad_pages_to: int = 1,
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Allocate an empty paged cache for ``n_layers`` attention layers.

    With a sliding window the per-sequence ring is only ``window/T + 1`` pages
    (rolling buffer); otherwise ``seq_len/T`` pages. ``pad_pages_to`` pads the
    pool's page count for even sharding across ``page_axes``. Returns
    (cache, lengths); each cache leaf is stacked over layers:
    pool_k (L, P, T, K, hd).
    """
    T = cfg.kv_page_tokens
    dtype = dtype or jnp.dtype(cfg.kv_cache_dtype)
    if cfg.sliding_window is not None and cfg.sliding_window < seq_len:
        ring = cfg.sliding_window // T + 1
    else:
        ring = max(seq_len // T, 1)
    n_pages = -(-(batch * ring) // pad_pages_to) * pad_pages_to
    K, hd = cfg.n_kv_heads, cfg.head_dim
    tables = jnp.arange(batch * ring, dtype=jnp.int32).reshape(batch, ring)
    page_pos = (jnp.arange(ring, dtype=jnp.int32) * T)[None, :].repeat(batch, 0)
    cache = {
        "pool_k": jnp.zeros((n_layers, n_pages, T, K, hd), dtype),
        "pool_v": jnp.zeros((n_layers, n_pages, T, K, hd), dtype),
        "tables": tables[None].repeat(n_layers, 0),
        "page_pos": page_pos[None].repeat(n_layers, 0),
    }
    if dtype == jnp.int8:  # per-(page, token, kv-head) dequant scales
        cache["scale_k"] = jnp.zeros((n_layers, n_pages, T, K), jnp.float32)
        cache["scale_v"] = jnp.zeros((n_layers, n_pages, T, K), jnp.float32)
    lengths = jnp.zeros((batch,), jnp.int32)
    return cache, lengths
