"""Model / run configuration.

One :class:`ModelConfig` instance per assigned architecture lives in
``repro/configs/<arch>.py``; reduced smoke variants are derived with
:meth:`ModelConfig.smoke`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # tokens; None = full attention

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style): one shared attention block every `attn_every`
    attn_every: int = 0

    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # input modality: "tokens" (LM), "embeds" (vlm/audio backbone stubs),
    # "encdec" (frame embeddings into encoder + tokens into decoder)
    input_kind: str = "tokens"

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # KV cache paging (the paper's pages, in tokens)
    kv_page_tokens: int = 64
    kv_cache_dtype: str = "bfloat16"

    # execution
    attn_chunk: int = 1024  # q-chunk for flash-style chunked attention
    remat: str = "full"  # full | dots | none
    remat_group: int = 1  # checkpoint every g layers (carries shrink g×)
    use_pallas: bool = False
    grad_accum: int = 1

    # long-context applicability (sub-quadratic decode path exists)
    supports_500k: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # -- derived -------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    # -- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; ``active_only`` counts top-k experts."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        dense_mlp = 3 * d * f  # gated SiLU MLP
        norms = 2 * d
        per_layer = attn + norms
        if self.is_moe:
            experts = self.top_k if active_only else self.n_experts
            per_layer += experts * 3 * d * f + d * self.n_experts  # experts + router
        elif self.family in ("ssm", "hybrid"):
            pass  # handled below
        else:
            per_layer += dense_mlp

        if self.family == "ssm" or self.family == "hybrid":
            di, n, g = self.d_inner, self.ssm_state, self.ssm_groups
            h = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * n + h)
            conv = (di + 2 * g * n) * self.ssm_conv
            out_proj = di * d
            ssm_layer = in_proj + conv + out_proj + 2 * h + di + d
            if self.family == "ssm":
                total_layers = self.n_layers * ssm_layer
            else:
                shared_attn = attn + dense_mlp + 2 * d
                n_attn = self.n_layers // max(self.attn_every, 1)
                total_layers = self.n_layers * ssm_layer + shared_attn + 0 * n_attn
            embed = v * d + d
            return total_layers + 2 * embed if self.family == "ssm" else total_layers + 2 * v * d + d

        if self.family == "encdec":
            enc_layer = attn + dense_mlp + norms
            dec_layer = attn + attn + dense_mlp + 3 * d  # self + cross
            total = self.n_enc_layers * enc_layer + self.n_dec_layers * dec_layer
            return total + 2 * v * d + d

        return self.n_layers * per_layer + 2 * v * d + d

    # -- smoke reduction --------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Tiny same-family config for CPU tests."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            capacity_factor=4.0,  # no capacity drops -> deterministic tests
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_dec_layers=2 if self.n_dec_layers else 0,
            sliding_window=32 if self.sliding_window else None,
            attn_chunk=32,
            kv_page_tokens=8,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
