"""Transformer / Mamba blocks + layer-stack scanning with remat."""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import mlp, mlp_init, rmsnorm, rmsnorm_init
from repro.models.modules import prefix_axes, stack_layer_params
from repro.parallel.axisinfo import AxisInfo, constrain_batch


def checkpoint_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ------------------------------ dense / moe block ------------------------------
def block_init(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    a_params, a_axes = attn.attention_init(ka, cfg)
    ln1, ln1_ax = rmsnorm_init(cfg)
    ln2, ln2_ax = rmsnorm_init(cfg)
    if cfg.is_moe:
        m_params, m_axes = moe_mod.moe_init(km, cfg)
    else:
        m_params, m_axes = mlp_init(km, cfg)
    params = {"ln1": ln1, "attn": a_params, "ln2": ln2, "ffn": m_params}
    axes = {"ln1": ln1_ax, "attn": a_axes, "ln2": ln2_ax, "ffn": m_axes}
    return params, axes


def block_apply(
    params, x: jnp.ndarray, cfg: ModelConfig, axis_info: Optional[AxisInfo],
    *, causal: bool = True,
    return_kv: bool = False,
):
    """Full-sequence block (train / prefill / encoder)."""
    h = rmsnorm(x, params["ln1"])
    if return_kv:
        a, kv = attn.attention_train(params["attn"], h, cfg, causal=causal, return_kv=True, axis_info=axis_info)
    else:
        a = attn.attention_train(params["attn"], h, cfg, causal=causal)
        kv = None
    x = x + a
    h = rmsnorm(x, params["ln2"])
    if cfg.is_moe:
        f, aux = moe_mod.moe_ffn(params["ffn"], h, cfg, axis_info)
    else:
        f, aux = mlp(params["ffn"], h, cfg), jnp.zeros((), jnp.float32)
    x = x + f
    return (x, aux, kv) if return_kv else (x, aux)


def block_decode(
    params, x: jnp.ndarray, cache: attn.CacheLayer, lengths: jnp.ndarray,
    cfg: ModelConfig, axis_info: Optional[AxisInfo],
):
    h = rmsnorm(x, params["ln1"])
    a, cache = attn.attention_decode(params["attn"], h, cache, lengths, cfg, axis_info)
    x = x + a
    h = rmsnorm(x, params["ln2"])
    if cfg.is_moe:
        f, _ = moe_mod.moe_ffn(params["ffn"], h, cfg, axis_info)
    else:
        f = mlp(params["ffn"], h, cfg)
    return x + f, cache


# ------------------------------ ssm block -----------------------------------------
def ssm_block_init(key, cfg: ModelConfig):
    s_params, s_axes = ssm_mod.ssm_init(key, cfg)
    ln, ln_ax = rmsnorm_init(cfg)
    return {"ln": ln, "ssm": s_params}, {"ln": ln_ax, "ssm": s_axes}


def ssm_block_apply(params, x, cfg: ModelConfig):
    return x + ssm_mod.ssm_forward(params["ssm"], rmsnorm(x, params["ln"]), cfg)


def ssm_block_decode(params, x, state, cfg: ModelConfig):
    y, state = ssm_mod.ssm_decode(params["ssm"], rmsnorm(x, params["ln"]), state, cfg)
    return x + y, state


# ------------------------------ stacked scans ------------------------------------
def stack_init(key, n_layers: int, init_one: Callable):
    """Stack per-layer params along axis 0; layer axes get a 'layers' prefix."""
    params = stack_layer_params(key, n_layers, lambda k: init_one(k)[0])
    _, axes = init_one(key)
    return params, prefix_axes(axes)


def scan_apply(params_stacked, x, body_fn, cfg: ModelConfig, axis_info=None):
    """lax.scan a block over stacked layer params; accumulates aux losses.

    ``cfg.remat_group = g`` scans groups of g layers under ONE checkpoint:
    residual carries shrink g× (saved every g layers) at no extra recompute
    FLOPs — the trade is a g× larger transient working set during each
    group's backward.
    """
    g = max(cfg.remat_group, 1)
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    if g > 1 and L % g == 0:
        grouped = jax.tree.map(lambda p: p.reshape(L // g, g, *p.shape[1:]), params_stacked)

        def group_fn(group_params, x):
            aux = jnp.zeros((), jnp.float32)
            for i in range(g):
                lp = jax.tree.map(lambda p: p[i], group_params)
                x, a = body_fn(lp, x)
                aux = aux + a
            return x, aux

        wrapped = checkpoint_wrap(group_fn, cfg)

        def body(carry, group_params):
            x, aux = carry
            x, a = wrapped(group_params, x)
            return (constrain_batch(x, axis_info), aux + a), None

        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), grouped)
        return x, aux

    wrapped = checkpoint_wrap(body_fn, cfg)

    def body(carry, layer_params):
        x, aux = carry
        x, a = wrapped(layer_params, x)
        return (constrain_batch(x, axis_info), aux + a), None

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), params_stacked)
    return x, aux


def scan_apply_collect_kv(params_stacked, x, body_fn, cfg: ModelConfig, axis_info=None):
    """Like scan_apply but also stacks per-layer (k, v) outputs (prefill)."""
    wrapped = checkpoint_wrap(body_fn, cfg)

    def body(carry, layer_params):
        x, aux = carry
        x, a, kv = wrapped(layer_params, x)
        return (constrain_batch(x, axis_info), aux + a), kv

    (x, aux), kvs = lax.scan(body, (x, jnp.zeros((), jnp.float32)), params_stacked)
    return x, aux, kvs


def scan_decode(params_stacked, x, cache, body_fn):
    """Scan a decode block over stacked layers and their cache slices."""

    def body(x, inp):
        layer_params, layer_cache = inp
        x, new_cache = body_fn(layer_params, x, layer_cache)
        return x, new_cache

    x, new_cache = lax.scan(body, x, (params_stacked, cache))
    return x, new_cache
