"""Minimal pure-JAX parameter system.

Parameters are nested dicts of ``jnp`` arrays. Every init function returns a
pair ``(params, axes)`` of identically-structured pytrees, where ``axes``
holds a tuple of *logical axis names* per array — the distribution layer
(``parallel/sharding.py``) maps logical names to mesh axes. Keeping the two
trees separate (rather than wrapping values) keeps params directly usable by
``jax.jit`` / optimizers without unwrapping.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Dict[str, Any]


def dense_init(key, in_dim: int, out_shape: Tuple[int, ...], dtype) -> jnp.ndarray:
    """Truncated-normal fan-in init for a projection ``(in_dim, *out_shape)``."""
    shape = (in_dim,) + tuple(out_shape)
    std = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def stack_layer_params(key, n_layers: int, init_one):
    """vmap a per-layer init over ``n_layers`` keys → stacked (L, ...) arrays."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def prefix_axes(axes_tree, name: str = "layers"):
    """Prepend a logical axis (for layer-stacked params) to every axes tuple."""
    return jax.tree.map(
        lambda a: (name,) + tuple(a), axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def validate_trees(params: Params, axes: Axes) -> None:
    """Assert params and axes trees are structurally identical and each axes
    tuple has one name per array dim."""
    pt = jax.tree.structure(params)
    at = jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple))
    if pt != at:
        raise ValueError(f"params/axes tree mismatch:\n{pt}\nvs\n{at}")
    for p, a in zip(
        jax.tree.leaves(params),
        jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple)),
    ):
        if np.ndim(p) != len(a):
            raise ValueError(f"axes {a} do not match array of shape {np.shape(p)}")


def param_bytes(params: Params) -> int:
    return sum(p.nbytes for p in jax.tree.leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, params
    )
