"""Version-portable ``shard_map`` import.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
top-level namespace, and its replication-check kwarg was renamed from
``check_rep`` to ``check_vma`` along the way. Model code imports
:func:`shard_map` from here and always passes the new-style ``check_vma``
kwarg; on older jax the shim forwards it as ``check_rep``.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

try:  # jax >= 0.6 style
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_CHECK_KWARG = "check_vma" if "check_vma" in _PARAMS else (
    "check_rep" if "check_rep" in _PARAMS else None
)


def shard_map(f: Callable[..., Any], **kwargs: Any) -> Callable[..., Any]:
    """Call the installed jax's shard_map, translating the check kwarg."""
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None and _CHECK_KWARG is not None:
        kwargs[_CHECK_KWARG] = check
    return _shard_map(f, **kwargs)


def axis_size(name: str):
    """``jax.lax.axis_size`` with a pre-0.5 fallback (psum of ones)."""
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


__all__ = ["shard_map", "axis_size"]
