"""Logical-axis → mesh-axis sharding rules (DP / FSDP / TP / EP).

Parameters carry logical axis names (see ``models/modules.py``); this module
resolves them to ``PartitionSpec``s against a concrete mesh, with automatic
fall-back to replication when a dimension does not divide the mesh axis
(e.g. 8 KV heads on a 16-way model axis).

Design choices (recorded in DESIGN.md §5):
  * batch → ``('pod','data')`` — pure DP across pods (DCN-friendly),
  * ``embed`` (d_model rows) → ``'data'`` — FSDP *within* a pod only; weights
    are replicated across pods and gradients all-reduce over ``'pod'``,
  * heads / ffn / vocab → ``'model'`` (TP),
  * experts → ``'model'`` when E divides it (EP), else per-expert ffn TP,
  * KV page pools → all axes jointly (the paper's page striping).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.parallel.axisinfo import AxisInfo


def logical_rules(cfg: ModelConfig, axis_info: AxisInfo) -> Dict[str, Any]:
    tp = axis_info.mesh.shape[axis_info.model_axis]
    moe_ep = cfg.is_moe and cfg.n_experts % tp == 0
    m = axis_info.model_axis
    return {
        "vocab": m,
        "embed": "data",  # FSDP within pod
        "embed_table": None,  # vocab-sharded only: FSDP'ing the table makes the
        # token gather reshard pathologically on multi-pod meshes
        "q_heads": m,
        "kv_heads": m,
        "head": None,
        "ffn": m,
        "moe_ffn": None if moe_ep else m,
        "experts": m if moe_ep else None,
        "experts_router": None,
        "layers": None,
        "groups": None,
        "conv": None,
        "ssm_proj": m,
        "ssm_conv_dim": m,
        "ssm_heads": None,
        "ssm_inner": m,
        "batch": axis_info.batch_axes,
        "pages": axis_info.page_axes,
        "seq": None,
    }


def spec_for(shape: Tuple[int, ...], axes: Tuple[str, ...], rules, mesh: Mesh) -> P:
    """Resolve one param's logical axes to a PartitionSpec, honoring
    divisibility and never assigning a mesh axis twice."""
    used = set()
    entries = []
    for dim, name in zip(shape, axes):
        mesh_axes = rules.get(name)
        if mesh_axes is None:
            entries.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        size = 1
        ok = True
        for a in mesh_axes:
            if a in used:
                ok = False
                break
            size *= mesh.shape[a]
        if not ok or dim % size:
            entries.append(None)
            continue
        used.update(mesh_axes)
        entries.append(mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(params_shape, axes_tree, cfg: ModelConfig, axis_info: AxisInfo):
    """NamedSharding tree for a params (or optimizer-state) pytree."""
    rules = logical_rules(cfg, axis_info)
    mesh = axis_info.mesh

    def one(p, a):
        return NamedSharding(mesh, spec_for(p.shape, a, rules, mesh))

    return jax.tree.map(
        one, params_shape, axes_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jnp.ndarray)),
    )


def batch_shardings(batch_spec, cfg: ModelConfig, axis_info: AxisInfo):
    """Input batches: shard dim 0 (batch) over DP axes when divisible."""
    mesh = axis_info.mesh
    n = axis_info.n_batch_shards

    def one(s):
        if s.shape and s.shape[0] % n == 0:
            return NamedSharding(mesh, P(axis_info.batch_axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_shardings(cache_shape, cfg: ModelConfig, axis_info: AxisInfo):
    """Decode-cache pytrees: page pools over all axes; small state replicated;
    SSM states over batch when divisible."""
    mesh = axis_info.mesh
    n_pages = axis_info.n_page_shards
    n_batch = axis_info.n_batch_shards

    def one(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("pool_k", "pool_v", "scale_k", "scale_v"):
            # (L, P, T, K, hd): stripe pages over every axis
            if s.shape[1] % n_pages == 0:
                return NamedSharding(mesh, P(None, axis_info.page_axes))
            return NamedSharding(mesh, P())
        if name in ("ssm", "conv"):
            # (L, B, ...): shard batch over DP axes
            if s.shape[1] % n_batch == 0:
                return NamedSharding(mesh, P(None, axis_info.batch_axes))
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P())  # tables, page_pos, lengths, enc_len

    return jax.tree_util.tree_map_with_path(
        one, cache_shape, is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jnp.ndarray))
    )
