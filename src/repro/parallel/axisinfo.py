"""Mesh axis bookkeeping shared by model code and the launcher."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisInfo:
    """Which mesh axes play which role.

    ``batch_axes`` shard the batch (pure DP): ``('data',)`` single-pod or
    ``('pod', 'data')`` multi-pod. ``model_axis`` is the TP/EP axis. The KV
    page pool is sharded over *all* axes (``page_axes``) — the TPU analogue of
    the paper's page striping across every data provider.
    """

    mesh: Mesh
    batch_axes: Tuple[str, ...]
    model_axis: str = "model"

    @property
    def page_axes(self) -> Tuple[str, ...]:
        return self.batch_axes + (self.model_axis,)

    @property
    def n_batch_shards(self) -> int:
        return int(jax.numpy.prod(jax.numpy.array([self.mesh.shape[a] for a in self.batch_axes])))

    @property
    def n_page_shards(self) -> int:
        n = 1
        for a in self.page_axes:
            n *= self.mesh.shape[a]
        return n

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def single_device_axis_info() -> Optional["AxisInfo"]:
    """None — model code treats None as 'run the local path directly'."""
    return None


def constrain(x, axis_info: Optional[AxisInfo], *spec):
    """with_sharding_constraint that is a no-op without an AxisInfo.

    Model code sprinkles these at block boundaries so GSPMD never loses batch
    sharding (a single gather from a sharded table can otherwise poison the
    whole graph into replication).
    """
    if axis_info is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(axis_info.mesh, P(*spec)))


def constrain_batch(x, axis_info: Optional[AxisInfo]):
    """Shard dim 0 (batch) over the DP axes; everything else unconstrained."""
    if axis_info is None:
        return x
    batch = x.shape[0]
    n = 1
    for a in axis_info.batch_axes:
        n *= axis_info.mesh.shape[a]
    if batch % n:
        return x
    spec = (axis_info.batch_axes,) + (None,) * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(x, NamedSharding(axis_info.mesh, P(*spec)))


def page_offset_in_shard(axis_names: Tuple[str, ...], pages_local: int):
    """Inside shard_map: first global page id owned by this rank."""
    from repro.parallel.compat import axis_size

    idx = 0
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name)
    return idx * pages_local
