"""Paged decode attention Pallas TPU kernel — the paper's striped-page READ
fused with attention.

Grid ``(B, K)`` (sequence × kv-head). For each sequence the kernel walks the
sequence's page table with a ``fori_loop``; every iteration DMAs one
``page_tokens × head_dim`` K/V page from the pool (kept in ANY/HBM memory
space — the pool is far too large for VMEM; this indirection IS the paper's
fine-grain remote read) into VMEM and accumulates online softmax for the
``G = H/K`` query heads of that kv-head.

The kernel emits *unnormalized* ``(o, m, l)`` so the shard_map wrapper can
split-K combine partial results across pool shards (flash-decoding), exactly
like the XLA path in ``ops._paged_local_xla``.

Ring-buffer (sliding-window) pages are handled through ``page_pos``: a page's
slot-0 absolute position decides token validity, so SWA rolling pools reuse
the same kernel.

Validated against ``ref.paged_attention_ref`` in interpret mode
(tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, tables_ref, page_pos_ref, lengths_ref, pool_k_ref, pool_v_ref,
            o_ref, m_ref, l_ref, *, T: int, R: int, P_loc: int, G: int,
            window: Optional[int], scale: float):
    b = pl.program_id(0)
    kvh = pl.program_id(1)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    length = lengths_ref[0, 0]
    lo = jnp.maximum(0, length - window) if window is not None else 0

    def body(r, carry):
        acc, m, l = carry  # (G, D) f32, (G, 1), (G, 1)
        pid = tables_ref[0, r]  # local page id (wrapper pre-subtracts offset)
        base = page_pos_ref[0, r]
        in_range = jnp.logical_and(pid >= 0, pid < P_loc)
        safe = jnp.clip(pid, 0, P_loc - 1)
        kp = pool_k_ref[safe, :, kvh, :].astype(jnp.float32)  # (T, D)
        vp = pool_v_ref[safe, :, kvh, :].astype(jnp.float32)
        s = lax.dot_general(q, kp, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, T)
        pos = base + lax.broadcasted_iota(jnp.int32, (1, T), 1)  # (1, T)
        valid = jnp.logical_and(pos >= lo, pos < length)
        valid = jnp.logical_and(valid, in_range)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new) * valid
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        pv = lax.dot_general(p, vp, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        return acc * alpha + pv, m_new, l_new

    G_, D = q.shape
    acc0 = jnp.zeros((G_, D), jnp.float32)
    m0 = jnp.full((G_, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G_, 1), jnp.float32)
    acc, m, l = lax.fori_loop(0, R, body, (acc0, m0, l0))
    o_ref[0, 0] = acc.astype(o_ref.dtype)
    m_ref[0, 0] = m[:, 0].astype(m_ref.dtype)
    l_ref[0, 0] = l[:, 0].astype(l_ref.dtype)


def paged_attention_pallas(
    q: jnp.ndarray,  # (B, H, D)
    pool_k: jnp.ndarray,  # (P_local, T, K, D)
    pool_v: jnp.ndarray,
    tables: jnp.ndarray,  # (B, R) GLOBAL page ids
    page_pos: jnp.ndarray,  # (B, R)
    lengths: jnp.ndarray,  # (B,)
    *,
    window: Optional[int] = None,
    page_offset=0,
    n_pages_total: int = 0,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns unnormalized (o (B,H,D) f32, m (B,H) f32, l (B,H) f32)."""
    B, H, D = q.shape
    P_loc, T, K, _ = pool_k.shape
    R = tables.shape[1]
    G = H // K
    scale = 1.0 / (D ** 0.5)

    tables_local = tables.astype(jnp.int32) - page_offset  # negatives -> skipped
    lengths2d = lengths.astype(jnp.int32).reshape(B, 1)
    qg = q.reshape(B, K, G, D)

    kernel = functools.partial(
        _kernel, T=T, R=R, P_loc=P_loc, G=G, window=window, scale=scale,
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, k: (b, k, 0, 0)),  # q
            pl.BlockSpec((1, R), lambda b, k: (b, 0)),  # tables (local ids)
            pl.BlockSpec((1, R), lambda b, k: (b, 0)),  # page_pos
            pl.BlockSpec((1, 1), lambda b, k: (b, 0)),  # lengths
            pl.BlockSpec(memory_space=pltpu.ANY),  # pool_k stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # pool_v
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, k: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, k: (b, k, 0)),
            pl.BlockSpec((1, 1, G), lambda b, k: (b, k, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, K, G), jnp.float32),
            jax.ShapeDtypeStruct((B, K, G), jnp.float32),
        ],
        interpret=interpret,
    )(qg, tables_local, page_pos.astype(jnp.int32), lengths2d, pool_k, pool_v)
    return o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)
