"""Pure-jnp oracles for every kernel. Small-shape, obviously-correct code —
the ground truth that Pallas kernels and XLA fast paths are tested against."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(..., K, D) -> (..., H, D) by repeating each kv head H/K times."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_start: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Quadratic-materialization attention. GQA via kv-head repetition.

    ``q_start`` is the absolute position of q[0] (for chunked/decode use).
    ``window`` masks keys more than ``window-1`` positions behind the query
    (sliding-window attention); ``causal`` masks future keys.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    k = repeat_kv(k, H)
    v = repeat_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = q_start + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray,  # (B, H, D) one query token per sequence
    pool_k: jnp.ndarray,  # (P, T, K, D) page pool (pre-rotated keys)
    pool_v: jnp.ndarray,  # (P, T, K, D)
    tables: jnp.ndarray,  # (B, R) int32 page ids into the pool
    page_pos: jnp.ndarray,  # (B, R) absolute position of each page's slot 0
    lengths: jnp.ndarray,  # (B,) tokens cached per sequence (incl. current)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Decode attention over the paged, possibly ring-buffered KV pool.

    A cached token in page-slot ``(r, t)`` of sequence ``b`` has absolute
    position ``page_pos[b, r] + t``; it participates iff
    ``lo <= pos < lengths[b]`` where ``lo = max(0, lengths[b]-window)``.
    """
    B, H, D = q.shape
    P, T, K, _ = pool_k.shape
    R = tables.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)

    k = pool_k[tables]  # (B, R, T, K, D)
    v = pool_v[tables]
    pos = page_pos[:, :, None] + jnp.arange(T)[None, None, :]  # (B, R, T)
    lo = jnp.maximum(0, lengths - window) if window is not None else jnp.zeros_like(lengths)
    valid = (pos >= lo[:, None, None]) & (pos < lengths[:, None, None])

    k = repeat_kv(k.reshape(B, R * T, K, D), H)
    v = repeat_kv(v.reshape(B, R * T, K, D), H)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = jnp.where(valid.reshape(B, 1, R * T), s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def online_softmax_combine(
    o_parts: jnp.ndarray,  # (N, ..., D) unnormalized sum exp(s-m)·v per part
    m_parts: jnp.ndarray,  # (N, ...)   running max per part
    l_parts: jnp.ndarray,  # (N, ...)   sum exp(s-m) per part
) -> jnp.ndarray:
    """Reference combine of flash/paged partial results (split-K check)."""
    m = jnp.max(m_parts, axis=0)
    alpha = jnp.exp(m_parts - m[None])
    l = jnp.sum(l_parts * alpha, axis=0)
    o = jnp.sum(o_parts * alpha[..., None], axis=0)
    return o / jnp.maximum(l[..., None], 1e-30)
