"""Jit-ready kernel wrappers with implementation dispatch.

``impl``:
  * ``"xla"``    — memory-feasible pure-XLA fast paths (chunked/online-softmax
                   formulations). Used on CPU, in the dry-run, and as GSPMD
                   building blocks.
  * ``"pallas"`` — TPU Pallas kernels (``flash_attention.py`` /
                   ``paged_attention.py``), validated in interpret mode.
  * ``"ref"``    — quadratic oracles from :mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ref as _ref

NEG_INF = -1e30


# =============================== flash attention ===============================
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_start: int = 0,
    q_chunk: int = 1024,
    impl: str = "xla",
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window, q_start=q_start)
    if impl == "pallas":
        from repro.kernels import flash_attention as _fa

        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_start=q_start, interpret=interpret
        )
    return _causal_tiled_flash(
        q, k, v, causal=causal, window=window, q_start=q_start, q_chunk=q_chunk
    )


def _causal_tiled_flash(q, k, v, *, causal, window, q_start, q_chunk):
    """Binary causal tiling around :func:`_flash_xla`.

    A causal S×S attention computed as a rectangle wastes ~2× FLOPs. The
    upper-half q-chunks genuinely need (almost) all keys, but the lower half
    only needs the first S/2 — so recurse on that half-size causal square:

        f(S) = f(S/2) + (S/2 rows × S keys)  ->  (2/3)·S²  vs  S²

    i.e. −33% attention FLOPs at full depth, in pure XLA with static shapes
    and bit-identical numerics (each query still sees exactly the same keys
    in the same chunk order). The Pallas kernel achieves the full 2× on TPU
    via per-block skipping; this recovers most of it for the XLA/roofline
    path (EXPERIMENTS.md §Perf).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    if (
        not causal
        or window is not None
        or Sq != Sk
        or q_start != 0
        or Sq < 2 * q_chunk
        or Sq % 2
    ):
        return _flash_xla(q, k, v, causal=causal, window=window, q_start=q_start, q_chunk=q_chunk)
    half = Sq // 2
    lo = _causal_tiled_flash(
        q[:, :half], k[:, :half], v[:, :half],
        causal=True, window=None, q_start=0, q_chunk=q_chunk,
    )
    hi = _flash_xla(
        q[:, half:], k, v, causal=True, window=None, q_start=half, q_chunk=q_chunk
    )
    return jnp.concatenate([lo, hi], axis=1)


def _flash_xla(q, k, v, *, causal, window, q_start, q_chunk):
    """lax.scan over q-chunks with fp32 softmax — flash-style memory profile.

    With a sliding window, each q-chunk only sees a static-size key slice of
    ``window + q_chunk`` tokens (O(S·w) work instead of O(S²)).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k = _ref.repeat_kv(k, H)
    v = _ref.repeat_kv(v, H)
    q_chunk = min(q_chunk, Sq)
    if Sq % q_chunk:
        q_chunk = next(c for c in range(q_chunk, 0, -1) if Sq % c == 0)
    nq = Sq // q_chunk
    scale = 1.0 / (D ** 0.5)

    windowed = window is not None and Sk > window + q_chunk
    w_k = min(Sk, (window or 0) + q_chunk) if windowed else Sk

    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, D), 1, 0)

    def body(_, inp):
        qc, i = inp
        chunk_start = q_start + i * q_chunk
        if windowed:
            start = jnp.clip(chunk_start - (w_k - q_chunk), 0, Sk - w_k)
            ks = lax.dynamic_slice_in_dim(k, start, w_k, axis=1)
            vs = lax.dynamic_slice_in_dim(v, start, w_k, axis=1)
            kpos = start + jnp.arange(w_k)
        else:
            ks, vs = k, v
            kpos = jnp.arange(Sk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, ks, preferred_element_type=jnp.float32)
        s = s * scale
        qpos = chunk_start + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, ks.shape[1]), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vs.dtype), vs, preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, outs = lax.scan(body, None, (qs, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)


# =============================== paged decode attention ===============================
def paged_attention(
    q: jnp.ndarray,  # (B, H, D) — one query token per sequence
    pool_k: jnp.ndarray,  # (P_local, T, K, D) — bf16/f32 or int8 (with scales)
    pool_v: jnp.ndarray,
    tables: jnp.ndarray,  # (B, R) global page ids
    page_pos: jnp.ndarray,  # (B, R) absolute position of slot 0 of each page
    lengths: jnp.ndarray,  # (B,) tokens cached (incl. the one just written)
    *,
    scale_k: Optional[jnp.ndarray] = None,  # (P_local, T, K) f32 for int8 pools
    scale_v: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
    page_offset=0,  # first global page id owned by this shard
    axis_names: Sequence[str] = (),
    block_pages: int = 8,
    impl: str = "xla",
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode attention over the paged pool (the paper's striped-page READ).

    When ``axis_names`` is non-empty this runs inside ``shard_map`` with the
    page pool sharded over those axes; partial online-softmax stats are
    combined with collectives (flash-decoding split-K).
    """
    if pool_k.dtype == jnp.int8:
        pool_k = dequantize_pool(pool_k, scale_k)
        pool_v = dequantize_pool(pool_v, scale_v)
    if impl == "ref" and not axis_names:
        return _ref.paged_attention_ref(
            q, pool_k, pool_v, tables, page_pos, lengths, window=window
        )
    n_shards = 1
    for name in axis_names:
        n_shards *= lax.psum(1, name)
    n_pages_total = pool_k.shape[0] * int(n_shards)

    if impl == "pallas":
        from repro.kernels import paged_attention as _pa

        o, m, l = _pa.paged_attention_pallas(
            q, pool_k, pool_v, tables, page_pos, lengths,
            window=window, page_offset=page_offset, n_pages_total=n_pages_total,
            interpret=interpret,
        )
    else:
        o, m, l = _paged_local_xla(
            q, pool_k, pool_v, tables, page_pos, lengths,
            window=window, page_offset=page_offset, n_pages_total=n_pages_total,
        )
    if axis_names:
        axis_names = tuple(axis_names)
        m_g = lax.pmax(m, axis_names)
        scale = jnp.exp(m - m_g)
        o = lax.psum(o * scale[..., None], axis_names)
        l = lax.psum(l * scale, axis_names)
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


INT8_MAX = 127.0


def quantize_token(x):
    """Per-(token, kv-head) symmetric int8 quantization: x (..., K, D) ->
    (q int8 (..., K, D), scale f32 (..., K))."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_pool(pool, scale):
    """(P,T,K,D) int8 × (P,T,K) f32 -> bf16 (in-kernel on TPU; explicit here)."""
    return (pool.astype(jnp.float32) * scale[..., None]).astype(jnp.bfloat16)


def page_ownership(tables, page_pos, n_pages_total):
    """Invert the page tables: for every pool page, which sequence owns it and
    the absolute position of its slot 0. Unowned (padding) pages get owner -1.

    This is the TPU-native schedule: each shard walks ITS pages (the paper's
    "each provider serves its own pages"), not every sequence's full table.
    """
    B, R = tables.shape
    owner = jnp.full((n_pages_total,), -1, jnp.int32)
    base = jnp.zeros((n_pages_total,), jnp.int32)
    b_idx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, R))
    owner = owner.at[tables.reshape(-1)].set(b_idx.reshape(-1), mode="drop")
    base = base.at[tables.reshape(-1)].set(page_pos.reshape(-1), mode="drop")
    return owner, base


def _paged_local_xla(q, pool_k, pool_v, tables, page_pos, lengths, *, window, page_offset,
                     block_pages=None, n_pages_total=None):
    """Owner-indexed online softmax over THIS shard's pages only.

    Work per shard = its local pages (flops ∝ P_local·T·H·D), not the global
    attention with masking. Returns unnormalized ``(o, m, l)`` per sequence
    for the split-K combine across shards.
    """
    B, H, D = q.shape
    P_loc, T, K, _ = pool_k.shape
    n_total = max(n_pages_total or 0, P_loc)
    scale = 1.0 / (D ** 0.5)
    G = H // K  # GQA group size

    owner_all, base_all = page_ownership(tables, page_pos, n_total)
    owner = lax.dynamic_slice_in_dim(owner_all, page_offset, P_loc)  # (P_loc,)
    base = lax.dynamic_slice_in_dim(base_all, page_offset, P_loc)

    ob = jnp.clip(owner, 0, B - 1)
    # grouped-head einsums: no (P,T,H,D) kv-head repetition materialized
    qp = q[ob].astype(pool_k.dtype).reshape(P_loc, K, G, D)
    s = jnp.einsum("pkgd,ptkd->pkgt", qp, pool_k, preferred_element_type=jnp.float32) * scale

    pos = base[:, None] + jnp.arange(T)[None, :]  # (P_loc, T)
    length_p = lengths[ob]  # (P_loc,)
    lo = jnp.maximum(0, length_p - window) if window is not None else jnp.zeros_like(length_p)
    valid = (owner[:, None] >= 0) & (pos >= lo[:, None]) & (pos < length_p[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)  # (P_loc, K, G, T)

    # segment (per-owner) online softmax via scatter-max / scatter-add;
    # masked pages contribute exact zeros / NEG_INF, so clip-aliasing to seq 0
    # is harmless.
    s_flat = s.reshape(P_loc, H, T)
    m = jnp.full((B, H), NEG_INF, jnp.float32).at[ob].max(s_flat.max(axis=-1), mode="drop")
    p = jnp.exp(s_flat - m[ob][..., None]) * valid[:, None, :]
    l = jnp.zeros((B, H), jnp.float32).at[ob].add(p.sum(axis=-1), mode="drop")
    pv = jnp.einsum(
        "pkgt,ptkd->pkgd", p.reshape(P_loc, K, G, T).astype(pool_v.dtype), pool_v,
        preferred_element_type=jnp.float32,
    ).reshape(P_loc, H, D)
    o = jnp.zeros((B, H, D), jnp.float32).at[ob].add(pv, mode="drop")
    return o, m, l


# =============================== paged cache update ===============================
def paged_update(
    pool_k: jnp.ndarray,  # (P_local, T, K, D)
    pool_v: jnp.ndarray,
    tables: jnp.ndarray,  # (B, R)
    page_pos: jnp.ndarray,  # (B, R)
    lengths: jnp.ndarray,  # (B,) tokens cached so far; new token lands at this position
    new_k: jnp.ndarray,  # (B, K, D) — pre-rotated
    new_v: jnp.ndarray,
    *,
    scale_k: Optional[jnp.ndarray] = None,  # (P_local, T, K) for int8 pools
    scale_v: Optional[jnp.ndarray] = None,
    page_offset=0,
):
    """COW-aware append of one token per sequence (the paper's page WRITE).

    Non-local pages are dropped by the scatter (each shard writes only the
    pages it owns). Returns ``(pool_k, pool_v, page_pos)``. The serving engine
    guarantees the target page is never shared (it COW-forks shared pages
    before scheduling the batch), so in-place pool donation is safe.
    """
    P_loc, T, K, D = pool_k.shape
    R = tables.shape[1]
    B = tables.shape[0]
    pos = lengths  # 0-indexed position of the incoming token
    r = (pos // T) % R
    slot = pos % T
    b_idx = jnp.arange(B)
    gid = tables[b_idx, r]
    local = gid - page_offset
    # non-local pages must become POSITIVE out-of-bounds (dropped); negative
    # scatter indices would WRAP and corrupt the tail of the local pool
    local = jnp.where((local >= 0) & (local < P_loc), local, P_loc)

    if pool_k.dtype == jnp.int8:
        qk, sk = quantize_token(new_k)
        qv, sv = quantize_token(new_v)
        pool_k = pool_k.at[local, slot].set(qk, mode="drop")
        pool_v = pool_v.at[local, slot].set(qv, mode="drop")
        scale_k = scale_k.at[local, slot].set(sk, mode="drop")
        scale_v = scale_v.at[local, slot].set(sv, mode="drop")
    else:
        pool_k = pool_k.at[local, slot].set(new_k.astype(pool_k.dtype), mode="drop")
        pool_v = pool_v.at[local, slot].set(new_v.astype(pool_v.dtype), mode="drop")
    # recycling a ring page: its slot-0 absolute position becomes pos
    new_base = jnp.where(slot == 0, pos, page_pos[b_idx, r])
    page_pos = page_pos.at[b_idx, r].set(new_base)
    if pool_k.dtype == jnp.int8:
        return pool_k, pool_v, page_pos, scale_k, scale_v
    return pool_k, pool_v, page_pos


def prefill_into_pages(
    k: jnp.ndarray,  # (B, S, K, D) pre-rotated
    v: jnp.ndarray,
    page_tokens: int,
    extra_pages: int = 1,
    pad_pages_to: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Lay out freshly pref't K/V as pages: request b's page p is global page
    ``b*R + p`` (provider-manager contiguous placement). ``extra_pages`` empty
    pages per sequence give decode headroom before the ring recycles;
    ``pad_pages_to`` pads the POOL page count (unreferenced tail pages) so it
    stays evenly shardable across the page axes. Returns
    (pool_k, pool_v, tables, page_pos)."""
    B, S, K, D = k.shape
    T = page_tokens
    assert S % T == 0
    Rf = S // T
    R = Rf + extra_pages
    pk = k.reshape(B, Rf, T, K, D)
    pv = v.reshape(B, Rf, T, K, D)
    if extra_pages:
        pad = jnp.zeros((B, extra_pages, T, K, D), k.dtype)
        pk = jnp.concatenate([pk, pad], axis=1)
        pv = jnp.concatenate([pv, pad], axis=1)
    pool_k = pk.reshape(B * R, T, K, D)
    pool_v = pv.reshape(B * R, T, K, D)
    n_pool = -(-(B * R) // pad_pages_to) * pad_pages_to
    if n_pool > B * R:
        tail = jnp.zeros((n_pool - B * R, T, K, D), k.dtype)
        pool_k = jnp.concatenate([pool_k, tail], axis=0)
        pool_v = jnp.concatenate([pool_v, tail], axis=0)
    tables = jnp.arange(B * R, dtype=jnp.int32).reshape(B, R)
    page_pos = (jnp.arange(R, dtype=jnp.int32) * T)[None, :].repeat(B, axis=0)
    return pool_k, pool_v, tables, page_pos
