"""Flash attention Pallas TPU kernel (causal + sliding-window, GQA).

Tiling: grid ``(B, H, nq, nk)`` — the minor-most ``nk`` axis iterates
sequentially on TPU, so the online-softmax state lives in VMEM scratch across
``nk`` steps and the output tile is emitted on the last one. Q/K/V tiles are
``(block_q|block_k) × head_dim`` VMEM blocks (head_dim padded to a lane
multiple of 128 by the wrapper in ``ops.py``); the MXU sees
``block_q × head_dim × block_k`` matmuls.

Causal / sliding-window block skipping: fully-masked K blocks are skipped via
``pl.when`` — this is the ~2× causal FLOP saving the XLA chunked path cannot
express (EXPERIMENTS.md §Perf).

Validated against ``ref.attention_ref`` in interpret mode on CPU
(tests/test_kernels.py); compiled path requires a real TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int], q_start: int,
            block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos0 = q_start + iq * block_q
    k_pos0 = ik * block_k
    # block-level skip: block fully in the future, or fully left of the window
    live = True
    if causal:
        live = k_pos0 <= q_pos0 + block_q - 1
    if window is not None:
        live = jnp.logical_and(live, k_pos0 + block_k - 1 > q_pos0 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qp = q_pos0 + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_pos0 + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new) * mask  # mask kills exp(-1e30 - -1e30) == 1
        l_ref[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ik == nk - 1)
    def _emit():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, H, D) — D already lane-aligned by ops.py
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_start: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert H % K == 0
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad sequences to block size"
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / (D ** 0.5)

    # layout: (B, heads, seq, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, q_start=q_start,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h * K // H, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h * K // H, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
