from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.trainer import make_train_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "make_train_step"]
