"""AdamW in pure JAX, with optimizer state sharded like the parameters
(ZeRO-style: the m/v trees inherit each param's FSDP/TP PartitionSpec)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step (fp32 math; params may be bf16 — updated via fp32 cast).

    Returns (new_params, new_state, metrics).
    """
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
