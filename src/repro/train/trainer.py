"""Training step factory: loss → grads (with optional microbatch gradient
accumulation) → AdamW, all as a single jit-able function."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.lm import Model
from repro.parallel.axisinfo import AxisInfo
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(model: Model, cfg: ModelConfig, axis_info: Optional[AxisInfo],
                    opt_cfg: AdamWConfig, param_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``cfg.grad_accum > 1`` splits the batch into microbatches scanned
    sequentially, accumulating fp32 gradients — trades step latency for
    activation memory (the standard large-model fit knob).

    ``param_shardings``: NamedSharding tree for the params. When given, each
    microbatch's gradients are constrained to it INSIDE the accumulation scan,
    so GSPMD reduce-scatters the per-microbatch grads (sharded like the
    params) instead of all-reducing the full gradient tree every microbatch —
    a ~(n_data−1)× collective-byte saving (EXPERIMENTS.md §Perf).
    """

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch, axis_info)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def shard_grads(grads):
        if param_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, param_shardings)

    n_batch_shards = 1
    if axis_info is not None:
        for a in axis_info.batch_axes:
            n_batch_shards *= axis_info.mesh.shape[a]

    def train_step(params, opt_state, batch):
        B0 = jax.tree.leaves(batch)[0].shape[0]
        # keep every microbatch divisible by the DP shard count; if the batch
        # itself is smaller than the shard count (tiny elastic runs), fall
        # back to A=1 with replicated batches
        A = max(1, min(cfg.grad_accum, B0 // max(n_batch_shards, 1)))
        while A > 1 and (B0 % A or (B0 // A) % n_batch_shards):
            A -= 1
        if A <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = shard_grads(jax.tree.map(lambda g: g.astype(jnp.float32), grads))
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            micro = jax.tree.map(lambda x: x.reshape(A, B // A, *x.shape[1:]), batch)
            mb0 = jax.tree.map(lambda x: x[0], micro)
            (loss0, metrics0), g0 = grad_fn(params, mb0)
            g0 = shard_grads(jax.tree.map(lambda g: g.astype(jnp.float32), g0))

            def body(carry, mb):
                g_acc, loss_acc, metrics_acc = carry
                (l, m), g = grad_fn(params, mb)
                g = shard_grads(jax.tree.map(lambda gg: gg.astype(jnp.float32), g))
                g_acc = jax.tree.map(lambda a, gg: a + gg, g_acc, g)
                metrics_acc = jax.tree.map(lambda a, mm: a + mm, metrics_acc, m)
                return (g_acc, loss_acc + l, metrics_acc), None

            rest = jax.tree.map(lambda x: x[1:], micro)
            (g_sum, loss_sum, metrics_sum), _ = lax.scan(
                body, (g0, loss0, metrics0), rest
            )
            grads = jax.tree.map(lambda g: g / A, g_sum)
            loss = loss_sum / A
            metrics = jax.tree.map(lambda m: m / A, metrics_sum)

        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def init_train_state(model: Model, key) -> Tuple[Any, Any, Any]:
    """(params, axes, opt_state) — concrete arrays (small configs / examples)."""
    params, axes = model.init(key)
    return params, axes, adamw_init(params)
