"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

Within a pod, gradients reduce over fast ICI; across pods they cross the slow
DCN link. This module compresses exactly that hop: per-pod-reduced gradients
are quantized to int8 with a per-tensor scale, all-reduced over the ``pod``
axis in int32, and dequantized — a ~4× wire saving on the slowest link. The
quantization error is carried in an error-feedback accumulator (EF-SGD), so
the bias vanishes over steps instead of accumulating.

Runs under ``shard_map`` over the full mesh: each leaf keeps its own
data/model PartitionSpec (passed in), and only the unmentioned ``pod`` axis is
reduced — so no resharding of the (possibly FSDP/TP-sharded) gradients is ever
triggered.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from repro.parallel.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.parallel.axisinfo import AxisInfo


def ef_init(grads_shape) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)


def _compress_one(g: jnp.ndarray, err: jnp.ndarray, axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis)  # sync scales (scalar — negligible bytes)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(1, axis)
    mean = q_sum.astype(jnp.float32) * scale / n
    return mean.astype(g.dtype), new_err


def compressed_pod_mean(grads, err_state, axis_info: AxisInfo, specs_tree, pod_axis: str = "pod"):
    """Mean-reduce ``grads`` over the pod axis with int8 EF compression.

    ``grads`` must already be identical within each pod (GSPMD's DP reduction
    guarantees this); ``specs_tree`` holds each leaf's PartitionSpec over the
    non-pod axes so nothing is resharded. Returns (grads_mean, new_err_state).
    """
    if pod_axis not in axis_info.mesh.axis_names:
        return grads, err_state  # single-pod: nothing to do

    mesh = axis_info.mesh
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    flat_s = jax.tree.leaves(specs_tree, is_leaf=lambda x: isinstance(x, P))

    def wrapped(*leaves):
        n = len(leaves) // 2
        outs = [_compress_one(g, e, pod_axis) for g, e in zip(leaves[:n], leaves[n:])]
        return tuple(o[0] for o in outs) + tuple(o[1] for o in outs)

    specs = tuple(flat_s) + tuple(flat_s)
    outs = shard_map(
        wrapped, mesh=mesh, in_specs=specs, out_specs=specs, check_vma=False
    )(*flat_g, *flat_e)
    out_g = jax.tree.unflatten(treedef, outs[: len(flat_g)])
    out_e = jax.tree.unflatten(treedef, outs[len(flat_g) :])
    return out_g, out_e
