"""chameleon-34b [vlm] — early-fusion backbone; VQ image tokenizer is a STUB
(input_specs provides precomputed patch/token embeddings).
[arXiv:2405.09818; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    input_kind="embeds",
    param_dtype="bfloat16",
    grad_accum=16,
    remat_group=2,
    supports_500k=False,
)
