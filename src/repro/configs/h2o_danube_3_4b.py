"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
    grad_accum=4,
    supports_500k=True,  # SWA -> sub-quadratic long-context decode
)
