"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,  # per-expert ffn
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    rope_theta=1000000.0,
    param_dtype="bfloat16",  # 235B total params
    grad_accum=8,
    remat_group=2,
    supports_500k=False,
)
