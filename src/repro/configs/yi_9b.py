"""yi-9b [dense] — llama-arch GQA. [arXiv:2403.04652; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10000.0,
    grad_accum=8,
    supports_500k=False,  # pure full attention -> long_500k skipped
)
