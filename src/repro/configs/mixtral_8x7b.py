"""mixtral-8x7b [moe] — 8 experts top-2, sliding window. [arXiv:2401.04088; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1000000.0,
    grad_accum=8,
    supports_500k=True,  # SWA
)
