"""mistral-large-123b [dense]. [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1000000.0,
    param_dtype="bfloat16",
    kv_cache_dtype="int8",  # per-token-scale quantized paged KV (§Perf hillclimb 3)  # 123B: fp32 params + fp32 adam would not fit one pod
    grad_accum=8,
    remat_group=2,
    supports_500k=False,
)
