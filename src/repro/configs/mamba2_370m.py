"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,   # attention-free; placeholder
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    grad_accum=4,
    supports_500k=True,  # O(1) recurrent decode state
)
