"""zamba2-2.7b [hybrid] — Mamba2 backbone + one shared attention block applied
every 6 layers. [arXiv:2411.15242; hf]

Simplification vs the HF checkpoint (noted in DESIGN.md): the shared block is
a plain pre-norm attn+MLP on the hidden stream (no concat-with-embedding input
and no per-application LoRA deltas).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2_7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,  # MHA shared block
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    attn_every=6,
    grad_accum=4,
    remat_group=2,
    supports_500k=True,  # hybrid: Mamba2 state + periodic attention
)
