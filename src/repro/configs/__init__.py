"""Assigned-architecture registry: ``get_config("<arch-id>")``."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "h2o-danube-3-4b",
    "yi-9b",
    "llama3_2-1b",
    "mistral-large-123b",
    "mixtral-8x7b",
    "qwen3-moe-235b-a22b",
    "zamba2-2_7b",
    "chameleon-34b",
    "mamba2-370m",
    "seamless-m4t-medium",
]

_ALIASES = {
    "llama3.2-1b": "llama3_2-1b",
    "zamba2-2.7b": "zamba2-2_7b",
}


def get_config(arch_id: str) -> ModelConfig:
    arch_id = _ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
