"""llama3.2-1b [dense] — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3_2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=64,
    rope_theta=500000.0,
    grad_accum=4,
    supports_500k=False,
)
