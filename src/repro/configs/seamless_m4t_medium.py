"""seamless-m4t-medium [audio] — enc-dec transformer backbone; the speech
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="audio",
    n_layers=24,  # 12 encoder + 12 decoder
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,  # MHA
    d_ff=4096,
    vocab_size=256206,
    input_kind="encdec",
    grad_accum=4,
    supports_500k=False,
)
