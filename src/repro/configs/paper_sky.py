"""The paper's own workload: the supernovae "sky view" blob (§V).

1 TB global string, 64 KB pages, segments of 16 KB - 16 MB accessed by
concurrent clients. Benchmarks (Fig. 3 reproductions) read these constants.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class SkyConfig:
    blob_size: int = 1 << 40  # 1 TB logical
    page_size: int = 64 << 10  # 64 KB
    segment_min: int = 16 << 10
    segment_max: int = 16 << 20
    hot_interval: int = 1 << 30  # clients touch a 1 GB working window
    n_data_providers: int = 20
    n_metadata_providers: int = 20
    # Grid'5000 Rennes cluster model (paper §V.B)
    latency_s: float = 0.1e-3
    bandwidth_Bps: float = 117.5e6


CONFIG = SkyConfig()
