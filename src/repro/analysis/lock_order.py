"""The repo's global lock hierarchy — the single source of truth.

Every lock in ``repro.core`` (and the lock-bearing satellites in
``repro.storage``) is declared here with a *level*: a thread may only acquire
a lock whose level is strictly greater than the level of every lock it
already holds. The static lint (:mod:`repro.analysis.lint`) checks acquisition
edges against this partial order at parse time; the runtime watchdog
(:mod:`repro.analysis.lockwatch`) records the actual acquisition graph and
reports any cycle — the two see the same names because lock construction goes
through :func:`repro.analysis.lockwatch.make_lock` with the declared name.

Levels (outermost → innermost):

======  ======================================================================
level   locks
======  ======================================================================
0       ``BlobCheckpointer._lock`` — serializes whole checkpoint passes; a
        save calls the full write plane AND ``Cluster.gc`` underneath
1       ``Cluster._gc_guard`` — serializes GC passes against snapshot pinning
2       ``ReplicaBalancer._rebalance_lock`` / ``RepairService._lock`` —
        promotion and re-replication/scrub passes; non-blocking for readers,
        deliberately held across data-plane copies (one aliases the other
        when both actors exist)
3       per-object bookkeeping locks that guard small registries and windows
        (session lists, async-write windows, coalesce queues, pin flags)
4       the shared actors' state locks (version manager, provider manager,
        pin table, balancer heat counters, aux-pool bring-up)
5       leaf locks: per-cache, per-provider, per-stats — never hold anything
        else while holding one of these
======  ======================================================================

``allow_blocking`` marks locks that are *designed* to be held across blocking
work (modeled-RTT RPCs, provider service sleeps). For every other lock, any
blocking call — ``time.sleep``, ``Future.result``, ``Event.wait``, executor
joins, the modeled RPC methods — inside its critical section is a lint
violation (rule ``blocking-under-lock``).

A lock that exists in the code but not here is itself a violation
(``undeclared-lock``): growing the concurrency surface requires declaring
where the new lock sits in the order.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class LockSpec:
    """One declared lock: its canonical name, hierarchy level, and whether it
    may be held across blocking calls."""

    name: str  #: canonical name, ``Class._attr`` — the make_lock() argument
    level: int  #: partial order: may acquire only strictly greater levels
    allow_blocking: bool = False
    note: str = ""


#: The declared hierarchy. Order within a level is irrelevant — locks of the
#: SAME level must never nest (for aliases of one underlying lock, nesting
#: would be a self-deadlock; for distinct locks it is an undeclared ordering).
LOCKS = [
    # -- level 0: checkpoint passes (blocking by design) ---------------------
    LockSpec("BlobCheckpointer._lock", 0, allow_blocking=True,
             note="serializes save/restore passes; a save holds it across "
                  "full blob writes AND the retention Cluster.gc call"),
    LockSpec("Federation._gc_lock", 0, allow_blocking=True,
             note="serializes federated GC passes; held across per-node "
                  "acks, RetryPolicy backoffs, lease-expiry waits and the "
                  "home node's Cluster.gc by design. Same level as "
                  "BlobCheckpointer._lock: a checkpointer must never wrap "
                  "a federated node (its retention gc would nest the two)"),
    # -- level 1: GC passes ---------------------------------------------------
    LockSpec("Cluster._gc_guard", 1, allow_blocking=True,
             note="serializes GC passes against snapshot creation; the pass "
                  "does metadata/provider RPCs under it by design"),
    # -- level 2: promotion / repair passes ----------------------------------
    LockSpec("ReplicaBalancer._rebalance_lock", 2, allow_blocking=True,
             note="readers try-lock and skip; held across page copies so "
                  "promotions serialize without queueing the read path"),
    LockSpec("Federation._fence_lock", 2, allow_blocking=True,
             note="per-node fence/rejoin transitions (one instance per "
                  "node); held across the node's cache purges (level 5) "
                  "and the coordinator join (level 3), so it must sit "
                  "BELOW the coordinator lock. Never nests the repair/"
                  "rebalance locks of this level"),
    LockSpec("RepairService._lock", 2, allow_blocking=True,
             note="re-replication/scrub passes; held across data-plane "
                  "copies like the rebalance lock. On clusters WITH a "
                  "balancer this name is never constructed — the service "
                  "ALIASES ReplicaBalancer._rebalance_lock so repair, "
                  "promotion and GC exclusion all serialize on one lock "
                  "(same level: the two names must never nest)"),
    # -- level 3: small registries / windows ---------------------------------
    LockSpec("Cluster._sessions_lock", 3),
    LockSpec("Cluster._membership_lock", 3),
    LockSpec("Cluster._warmers_lock", 3),
    LockSpec("Session._async_lock", 3),
    LockSpec("Session._writer_pool_lock", 3),
    LockSpec("Snapshot._pin_lock", 3),
    LockSpec("StridePrefetcher._lock", 3),
    LockSpec("_PageFetchStream._lock", 3),
    LockSpec("WatchWarmer._cv", 3,
             note="condition over its own lock; warmer rendezvous only"),
    LockSpec("MetadataDHT._coalesce_lock", 3),
    LockSpec("MetadataDHT._executor_lock", 3),
    LockSpec("BlobStore._handles_lock", 3),
    LockSpec("GcEpochCoordinator._lock", 3,
             note="epoch counter, per-node leases, federated pin tables "
                  "and node health; no RPC ever runs under it"),
    LockSpec("GcEpochCoordinator._cv", 3,
             note="condition ALIASING GcEpochCoordinator._lock (the "
                  "VersionManager._published_cv pattern): pins wait on it "
                  "while a GC sweep is in progress; nesting the two names "
                  "is a self-deadlock"),
    LockSpec("FaultInjector._lock", 3,
             note="guards the chaos harness's op counter and pending "
                  "fault queues; fault ACTIONS (kill/recover/sleep) run "
                  "outside it"),
    LockSpec("PageDirectory._lock", 3,
             note="the content-addressed page registry (dict/LRU/refcounts "
                  "only); version pins are taken BEFORE it (they nest the "
                  "level-1 gc guard) and eviction hooks/unpins fire OUTSIDE "
                  "it — same level as BlobKVStore._lock: never nest the two"),
    LockSpec("BlobKVStore._lock", 3,
             note="KV page-pool slot free-list + refcounts; directory "
                  "eviction (which re-enters the level-4 pin table) is "
                  "always called with this RELEASED"),
    # -- level 4: shared-actor state -----------------------------------------
    LockSpec("Cluster._aux_lock", 4),
    LockSpec("Cluster._pins_lock", 4),
    LockSpec("VersionManager._lock", 4),
    LockSpec("VersionManager._published_cv", 4,
             note="condition ALIASING VersionManager._lock — same underlying "
                  "lock, so nesting the two names is a self-deadlock (equal "
                  "levels forbid it)"),
    LockSpec("ProviderManager._lock", 4),
    LockSpec("MetadataDHT._health_lock", 4,
             note="shard health records (failure window + dead set), the "
                  "metadata mirror of ProviderManager._lock; on_dead fires "
                  "OUTSIDE it"),
    LockSpec("ReplicaBalancer._heat_lock", 4),
    # -- level 5: leaves ------------------------------------------------------
    LockSpec("PageCache._lock", 5),
    LockSpec("DataProvider._lock", 5, allow_blocking=True,
             note="page_service_seconds sleeps UNDER the lock on purpose: a "
                  "provider with finite service bandwidth is the paper's "
                  "network model (hot provider = bottleneck)"),
    LockSpec("TrafficStats._lock", 5),
]

BY_NAME: Dict[str, LockSpec] = {spec.name: spec for spec in LOCKS}

#: attribute-suffix → spec, only for suffixes that are unambiguous across the
#: registry (``_lock`` is not; ``_gc_guard`` is) — lets the lint resolve
#: acquisitions through foreign receivers like ``cluster._gc_guard``.
_suffix_counts: Dict[str, int] = {}
for _spec in LOCKS:
    _suffix_counts[_spec.name.split(".")[-1]] = (
        _suffix_counts.get(_spec.name.split(".")[-1], 0) + 1
    )
BY_UNIQUE_ATTR: Dict[str, LockSpec] = {
    spec.name.split(".")[-1]: spec
    for spec in LOCKS
    if _suffix_counts[spec.name.split(".")[-1]] == 1
}


def get(name: str) -> Optional[LockSpec]:
    return BY_NAME.get(name)


def allows_blocking(name: str) -> bool:
    """Whether ``name`` may be held across blocking calls. Unknown locks
    default to ``False`` — an undeclared lock gets the strict rules."""
    spec = BY_NAME.get(name)
    return spec.allow_blocking if spec is not None else False


def order_violation(held: str, acquiring: str) -> Optional[str]:
    """Return a human-readable reason if acquiring ``acquiring`` while holding
    ``held`` breaks the declared partial order, else ``None``. Unknown locks
    are not ordered here (the lint reports them separately as
    ``undeclared-lock``)."""
    a, b = BY_NAME.get(held), BY_NAME.get(acquiring)
    if a is None or b is None:
        return None
    if held == acquiring:
        return f"re-acquiring non-reentrant {held} (self-deadlock)"
    if b.level < a.level:
        return (
            f"acquires {acquiring} (level {b.level}) while holding {held} "
            f"(level {a.level}) — edges must go strictly downward in the "
            f"declared hierarchy"
        )
    if b.level == a.level:
        return (
            f"acquires {acquiring} while holding {held}: both level "
            f"{a.level} — same-level locks must never nest"
        )
    return None
