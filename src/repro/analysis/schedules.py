"""Deterministic interleaving explorer for cross-actor coherence scenarios.

The store's coherence argument rests on a small number of cross-thread
interactions: a writer publishing while a reader fills the shared tier, GC
racing a snapshot pin, async-write windows racing ``flush``, the watch
warmer racing demand reads. Production runs sample one arbitrary
interleaving per execution; this module instead *enumerates every bounded
interleaving* of those actors cooperatively and asserts the coherence
invariant after every step of every schedule.

Model: each scenario provides actors as ordered step lists (plain callables
against a freshly built cluster). A *schedule* is one interleaving of the
steps that preserves each actor's order — exactly the schedules a
sequentially consistent machine could produce at API granularity. For every
schedule the scenario world is rebuilt from scratch, the steps run in
schedule order on ONE thread (so there is no hidden nondeterminism), and the
invariant is evaluated after every step:

* the **shared cache tier only ever holds pages of published versions**
  (:func:`shared_tier_violations` — the paper's frontier rule), and
* any scenario-specific checks recorded in ``ctx.errors`` (torn reads,
  lost pins, dropped writes).

This is not a model checker over arbitrary preemption points — steps are
atomic API calls — but every ordering bug reachable at API granularity is
found exhaustively, deterministically, and with a replayable schedule
trace. The interleaving count is the multinomial coefficient of the step
counts, so scenarios stay small by construction; :func:`explore` refuses
(rather than silently truncates) scenarios whose schedule count exceeds
``max_schedules``.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import traceback
from types import SimpleNamespace
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Scenario",
    "Failure",
    "Report",
    "explore",
    "interleavings",
    "shared_tier_violations",
    "SCENARIOS",
    "run_all",
]


# -- schedule enumeration ----------------------------------------------------

def n_interleavings(counts: Sequence[int]) -> int:
    total, denom = sum(counts), 1
    for c in counts:
        denom *= math.factorial(c)
    return math.factorial(total) // denom


def interleavings(counts: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    """Every merge of ``counts[i]`` ordered steps per actor ``i`` that
    preserves each actor's internal order, in lexicographic actor order."""
    remaining = list(counts)

    def rec(prefix: List[int]) -> Iterator[Tuple[int, ...]]:
        if not any(remaining):
            yield tuple(prefix)
            return
        for i, left in enumerate(remaining):
            if left:
                remaining[i] -= 1
                prefix.append(i)
                yield from rec(prefix)
                prefix.pop()
                remaining[i] += 1

    return rec([])


# -- scenario protocol -------------------------------------------------------

@dataclasses.dataclass
class Scenario:
    """``build`` creates a fresh world (returns a ctx namespace that MUST
    carry ``cluster`` and an ``errors`` list); ``actors`` returns
    ``[(actor_name, [step, ...]), ...]`` with steps closed over the ctx;
    ``finalize`` (optional) quiesces the world before the last invariant
    evaluation (e.g. a final ``flush``)."""

    name: str
    build: Callable[[], SimpleNamespace]
    actors: Callable[[SimpleNamespace], List[Tuple[str, List[Callable[[], None]]]]]
    finalize: Optional[Callable[[SimpleNamespace], None]] = None


@dataclasses.dataclass(frozen=True)
class Failure:
    scenario: str
    schedule: Tuple[str, ...]  # actor step labels in execution order
    step: int  # index into schedule after which the invariant broke
    errors: Tuple[str, ...]

    def __str__(self) -> str:
        trace = " -> ".join(
            f"[{s}]" if i == self.step else s
            for i, s in enumerate(self.schedule)
        )
        errs = "; ".join(self.errors)
        return f"{self.scenario}: schedule {trace}: {errs}"


@dataclasses.dataclass
class Report:
    scenario: str
    n_schedules: int
    n_steps: int
    failures: List[Failure]

    @property
    def ok(self) -> bool:
        return not self.failures

    def __str__(self) -> str:
        status = "OK" if self.ok else f"{len(self.failures)} FAILING"
        return (
            f"{self.scenario}: {self.n_schedules} schedules x "
            f"{self.n_steps} steps — {status}"
        )


# -- the coherence invariant -------------------------------------------------

def shared_tier_violations(cluster) -> List[str]:
    """The paper's frontier rule: the SHARED cache tier may only ever hold
    pages of published, non-aborted versions (private session caches may
    hold a writer's own unpublished pages; the shared tier never may).
    Returns one message per offending (blob, version)."""
    cache = getattr(cluster, "shared_cache", None)
    if cache is None:
        return []
    vm = cluster.version_manager
    out: List[str] = []
    for blob_id in vm.blob_ids():
        for version in cache.cached_versions(blob_id):
            if version == 0:
                continue  # v0 is the implicit all-zeros base, always readable
            if not vm.is_published(blob_id, version):
                out.append(
                    f"shared tier holds blob {blob_id} v{version} "
                    f"which is not published"
                )
            elif vm.is_aborted(blob_id, version):
                out.append(
                    f"shared tier holds blob {blob_id} v{version} "
                    f"which was aborted"
                )
    return out


def _invariant(ctx: SimpleNamespace) -> List[str]:
    out = shared_tier_violations(ctx.cluster)
    out.extend(ctx.errors)
    ctx.errors = []
    return out


# -- the explorer ------------------------------------------------------------

def explore(scenario: Scenario, max_schedules: int = 512) -> Report:
    """Run ``scenario`` under EVERY interleaving of its actors' steps,
    rebuilding the world per schedule and checking the invariant after every
    step. Raises ``ValueError`` if the schedule space exceeds
    ``max_schedules`` — bound the scenario, don't sample it silently."""
    probe = scenario.build()
    try:
        actor_list = scenario.actors(probe)
    finally:
        probe.cluster.close()
    counts = [len(steps) for _, steps in actor_list]
    total = n_interleavings(counts)
    if total > max_schedules:
        raise ValueError(
            f"{scenario.name}: {total} interleavings exceed the "
            f"max_schedules bound of {max_schedules} — shrink the scenario"
        )

    failures: List[Failure] = []
    n_run = 0
    for order in interleavings(counts):
        n_run += 1
        ctx = scenario.build()
        actors = scenario.actors(ctx)
        cursors = [0] * len(actors)
        labels: List[str] = []
        try:
            broke = False
            for idx, actor_i in enumerate(order):
                name, steps = actors[actor_i]
                step = steps[cursors[actor_i]]
                labels.append(f"{name}.{cursors[actor_i]}")
                cursors[actor_i] += 1
                try:
                    step()
                except Exception:
                    ctx.errors.append(
                        f"step {labels[-1]} raised:\n"
                        + traceback.format_exc(limit=4)
                    )
                errors = _invariant(ctx)
                if errors:
                    failures.append(Failure(
                        scenario.name, tuple(labels), idx, tuple(errors)))
                    broke = True
                    break
            if not broke and scenario.finalize is not None:
                try:
                    scenario.finalize(ctx)
                except Exception:
                    ctx.errors.append(
                        "finalize raised:\n" + traceback.format_exc(limit=4))
                errors = _invariant(ctx)
                if errors:
                    failures.append(Failure(
                        scenario.name, tuple(labels), len(order), tuple(errors)))
        finally:
            ctx.cluster.close()
    return Report(scenario.name, n_run, sum(counts), failures)


# -- world builders ----------------------------------------------------------

_PAGE = 256  # tiny pages keep every schedule's build cheap
_PAGES = 4


def _fill(value: int, n_bytes: int = _PAGE * _PAGES) -> np.ndarray:
    return np.full(n_bytes, value % 251, dtype=np.uint8)


def _base_ctx(shared_cache: bool = True) -> SimpleNamespace:
    from repro.core.cluster import Cluster

    cluster = Cluster(
        n_data_providers=2,
        n_metadata_providers=2,
        max_workers=2,
        shared_cache_bytes=(1 << 20) if shared_cache else 0,
        hot_replicas=False,
    )
    ctx = SimpleNamespace(cluster=cluster, errors=[])
    ctx.blob_id = cluster.alloc(_PAGE * _PAGES, _PAGE)
    return ctx


def _check_uniform(ctx: SimpleNamespace, data: np.ndarray, label: str) -> None:
    values = set(np.unique(data).tolist())
    published = {
        v % 251
        for v in range(
            0, ctx.cluster.version_manager.latest_published(ctx.blob_id) + 1
        )
    }
    if len(values) > 1:
        ctx.errors.append(
            f"{label}: torn read mixes page values {sorted(values)}")
    elif values and not values <= published:
        ctx.errors.append(
            f"{label}: read returned value {sorted(values)} which no "
            f"published version ever wrote")


# -- scenario: publish frontier vs shared-tier fill --------------------------

def _build_publish_vs_fill() -> SimpleNamespace:
    ctx = _base_ctx()
    ctx.writer = ctx.cluster.session()
    ctx.reader = ctx.cluster.session(cache_bytes=0)  # all fills hit shared tier
    ctx.whandle = ctx.writer.open(ctx.blob_id)
    ctx.rhandle = ctx.reader.open(ctx.blob_id)
    ctx.whandle.write(_fill(1), 0)  # v1 published before the race starts
    return ctx


def _actors_publish_vs_fill(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    def write(value):
        return lambda: ctx.whandle.write(_fill(value), 0)

    def read():
        def step():
            data = ctx.rhandle.read(0, _PAGE * _PAGES).data
            _check_uniform(ctx, data, "demand read")
        return step

    return [
        ("writer", [write(2), write(3)]),
        ("reader", [read(), read(), read()]),
    ]


# -- scenario: Cluster.gc vs Snapshot pin ------------------------------------

def _build_gc_vs_pin() -> SimpleNamespace:
    ctx = _base_ctx()
    ctx.session = ctx.cluster.session()
    ctx.handle = ctx.session.open(ctx.blob_id)
    ctx.handle.write(_fill(1), 0)  # v1
    ctx.handle.write(_fill(2), 0)  # v2
    ctx.snap = None
    ctx.gc_done = False
    ctx.pinned_before_gc = False
    return ctx


def _actors_gc_vs_pin(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    def pin():
        ctx.snap = ctx.handle.at(1)
        # the pin contract protects against FUTURE GC passes only: pinning
        # after a completed pass succeeds but the first read fails
        # ("the pin protects the future, not the past" — BlobHandle.at)
        ctx.pinned_before_gc = not ctx.gc_done

    def read_pinned():
        if ctx.snap is None:
            return
        try:
            data = ctx.snap.read(0, _PAGE * _PAGES)
        except (KeyError, ValueError) as exc:
            if ctx.pinned_before_gc:
                ctx.errors.append(
                    f"v1 was pinned BEFORE the GC pass yet the pinned read "
                    f"failed: {exc!r}")
            return  # pin lost the race to a completed pass: the contract
        if not (data == _fill(1)).all():
            ctx.errors.append("pinned v1 read returned non-v1 data")

    def release():
        if ctx.snap is not None:
            ctx.snap.release()

    def gc():
        ctx.cluster.gc(ctx.blob_id, [2])
        ctx.gc_done = True

    return [
        ("pinner", [pin, read_pinned, release]),
        ("collector", [gc]),
    ]


# -- scenario: Cluster.gc vs a shared-tier cached read -----------------------

def _build_gc_vs_cached_read() -> SimpleNamespace:
    ctx = _base_ctx()
    ctx.session = ctx.cluster.session(cache_bytes=0)
    ctx.handle = ctx.session.open(ctx.blob_id)
    ctx.handle.write(_fill(1), 0)  # v1
    ctx.handle.write(_fill(2), 0)  # v2
    ctx.handle.read(0, _PAGE * _PAGES, version=1)  # shared tier holds v1
    return ctx


def _actors_gc_vs_cached_read(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    def read_v1():
        try:
            data = ctx.handle.read(0, _PAGE * _PAGES, version=1).data
        except (KeyError, ValueError):
            return  # v1 already collected: failing the read is the contract
        if not (data == _fill(1)).all():
            ctx.errors.append(
                "read of retained v1 returned non-v1 data (stale or torn "
                "cache fill survived GC)")

    def gc():
        ctx.cluster.gc(ctx.blob_id, [2])

    return [
        ("reader", [read_v1, read_v1]),
        ("collector", [gc]),
    ]


# -- scenario: write_async window vs flush -----------------------------------

def _build_write_async_vs_flush() -> SimpleNamespace:
    ctx = _base_ctx()
    ctx.session = ctx.cluster.session()
    ctx.handle = ctx.session.open(ctx.blob_id)
    ctx.handle.write(_fill(1), 0)  # v1
    return ctx


def _actors_write_async_vs_flush(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    def write_async(value):
        return lambda: ctx.handle.write_async(_fill(value), 0)

    def flush():
        ctx.session.flush()

    return [
        ("writer", [write_async(2), write_async(3)]),
        ("flusher", [flush, flush]),
    ]


def _finalize_write_async_vs_flush(ctx) -> None:
    ctx.session.flush()
    latest = ctx.handle.latest_published()
    if latest != 3:
        ctx.errors.append(
            f"after final flush, frontier is v{latest}, expected v3 — an "
            f"async write was dropped or published out of order")
    data = ctx.handle.read(0, _PAGE * _PAGES).data
    if not (data == _fill(3)).all():
        ctx.errors.append("final read does not see the last async write")


# -- scenario: WatchWarmer fill vs demand read -------------------------------

def _build_warmer_vs_demand() -> SimpleNamespace:
    ctx = _base_ctx()
    ctx.session = ctx.cluster.session(cache_bytes=0)
    ctx.handle = ctx.session.open(ctx.blob_id)
    ctx.handle.write(_fill(1), 0)  # v1
    # frame_versions far beyond any version this scenario publishes: the
    # warmer's own thread never fires, so every warm pass below is a
    # deterministic explorer step instead of a background race
    ctx.warmer = ctx.cluster.warm_on_publish(
        ctx.blob_id, frame_versions=1 << 30)
    return ctx


def _actors_warmer_vs_demand(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    def publish(value):
        return lambda: ctx.handle.write(_fill(value), 0)

    def warm():
        version = ctx.handle.latest_published()
        ctx.warmer._warm(version)

    def read():
        data = ctx.handle.read(0, _PAGE * _PAGES).data
        _check_uniform(ctx, data, "demand read vs warmer")

    return [
        ("publisher", [publish(2)]),
        ("warmer", [warm, warm]),
        ("detector", [read, read]),
    ]


# -- scenario: metadata shard failover vs concurrent publish ------------------

def _build_shard_failover_vs_publish() -> SimpleNamespace:
    from repro.core.cluster import Cluster
    from repro.core.dht import RetryPolicy

    cluster = Cluster(
        n_data_providers=2,
        n_metadata_providers=2,
        metadata_replication=2,  # every node on BOTH shards: failover always has a home
        max_workers=2,
        shared_cache_bytes=0,  # every read re-traverses the metadata plane
        hot_replicas=False,
        retry_policy=RetryPolicy(max_attempts=1, sleep=lambda s: None),
    )
    ctx = SimpleNamespace(cluster=cluster, errors=[])
    ctx.blob_id = cluster.alloc(_PAGE * _PAGES, _PAGE)
    ctx.session = cluster.session(cache_bytes=0)
    ctx.handle = ctx.session.open(ctx.blob_id)
    ctx.handle.write(_fill(1), 0)  # v1 on both replicas before the race
    return ctx


def _actors_shard_failover_vs_publish(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    """A metadata shard dies, rejoins blank of the versions published while
    it was down, and is re-replicated — all racing a writer that keeps
    publishing and a reader that keeps traversing. The reader must NEVER
    observe a torn tree (an inner node resolved on one replica pointing at a
    leaf state the other replica never stored): every read is uniform and a
    value some published version actually wrote."""

    def publish(value):
        return lambda: ctx.handle.write(_fill(value), 0)

    def kill():
        ctx.cluster.metadata.fail_shard(0)

    def rejoin():
        # rejoins LIVE but stale: nodes published during the outage are
        # missing until the repair step — the classic torn-tree window
        ctx.cluster.metadata.recover_shard(0)

    def repair():
        ctx.cluster.repair_service.run_once()

    def read():
        data = ctx.handle.read(0, _PAGE * _PAGES).data
        _check_uniform(ctx, data, "read across shard failover")

    return [
        ("writer", [publish(2), publish(3)]),
        ("failover", [kill, rejoin, repair]),
        ("reader", [read, read]),
    ]


def _finalize_shard_failover_vs_publish(ctx) -> None:
    metadata = ctx.cluster.metadata
    if metadata.dead_shards() or metadata.shards[0].failed:
        metadata.recover_shard(0)
    ctx.cluster.repair_service.run_once()
    data = ctx.handle.read(0, _PAGE * _PAGES).data
    if not (data == _fill(3)).all():
        ctx.errors.append(
            "after failover + repair the frontier read is not v3's data")
    # replication whole again: every journal-covered node on BOTH shards
    vm = ctx.cluster.version_manager
    published, aborted = vm.repair_horizon(ctx.blob_id)
    for key, node in metadata.iter_nodes(ctx.blob_id):
        if key.version > published or key.version in aborted:
            continue
        for sid in metadata._replica_ids(key):
            if metadata.shards[sid].get(key) is None:
                ctx.errors.append(
                    f"replica {sid} missing {key} after failover repair")


def _build_node_death_vs_gc_ack() -> SimpleNamespace:
    from repro.core.dht import HealthConfig, RetryPolicy
    from repro.core.federation import Federation

    class _Clock:
        def __init__(self) -> None:
            self.t = 0.0

        def __call__(self) -> float:
            return self.t

        def advance(self, dt: float) -> None:
            self.t += dt

    clock = _Clock()
    fed = Federation(
        n_nodes=2,
        n_data_providers=2,
        n_metadata_providers=2,
        max_workers=2,
        lease_seconds=5.0,
        clock=clock,
        # sleeps (ack backoff, lease wait-out) advance the fake clock, so a
        # wait-out terminates deterministically inside one atomic step
        retry_policy=RetryPolicy(max_attempts=1, sleep=clock.advance),
        # dead_after=2: ONE failed ack leaves the node suspect (the GC pass
        # waits its lease out); a SECOND failed ack is the death verdict
        health=HealthConfig(
            dead_after=2, window_seconds=1e9, clock=clock
        ),
    )
    ctx = SimpleNamespace(cluster=fed, errors=[])
    ctx.fed = fed
    ctx.clock = clock
    ctx.blob_id = fed.nodes[0].alloc(_PAGE * _PAGES, _PAGE)
    ctx.s0 = fed.nodes[0].session()
    ctx.s1 = fed.nodes[1].session(cache_bytes=0)  # fills hit node 1's shared tier
    ctx.h0 = ctx.s0.open(ctx.blob_id)
    ctx.h1 = ctx.s1.open(ctx.blob_id)
    ctx.h0.write(_fill(1), 0)  # v1 published before the race
    ctx.h1.read(0, _PAGE * _PAGES)  # node 1's shared tier holds v1
    return ctx


def _actors_node_death_vs_gc_ack(ctx) -> List[Tuple[str, List[Callable[[], None]]]]:
    """A federated GC pass needs node 1's ack (purge + rejoin at the new
    epoch) while node 1 is partitioned from the coordinator, declared dead,
    or rejoining — in every order. Whatever the interleaving: node 1's reads
    stay uniform (its data plane works while partitioned), and after any
    read with a lapsed/reclaimed lease the node is FENCED (or already
    rejoined at the current epoch) — never serving cached pages past its
    lease."""
    fed = ctx.fed

    def partition():
        fed.apply_node_fault(1, "partition")

    def recover():
        fed.apply_node_fault(1, "recover")

    def gc():
        latest = fed.version_manager.latest_published(ctx.blob_id)
        fed.gc(ctx.blob_id, keep_versions=[latest])

    def write():
        ctx.h0.write(_fill(2), 0)

    def read():
        data = ctx.h1.read(0, _PAGE * _PAGES).data
        _check_uniform(ctx, data, "node-1 read across GC/death race")
        if not (fed.coordinator.lease_valid(1) or fed.node_fenced(1)):
            ctx.errors.append(
                "node 1 served with neither a valid lease nor its fence up"
            )

    return [
        ("chaos", [partition, recover]),
        ("gc", [gc, gc]),
        ("writer", [write]),
        ("reader", [read]),
    ]


def _finalize_node_death_vs_gc_ack(ctx) -> None:
    fed = ctx.fed
    fed.apply_node_fault(1, "recover")
    # a rejoined node starts from purged tiers: nothing it cached before the
    # outage can have survived the GC passes it missed
    cached = fed.nodes[1].shared_cache.cached_versions(ctx.blob_id)
    if cached:
        ctx.errors.append(
            f"node 1 rejoined with stale cached versions {cached}"
        )
    latest = fed.version_manager.latest_published(ctx.blob_id)
    data = ctx.h1.read(0, _PAGE * _PAGES).data
    if not (data == _fill(latest)).all():
        ctx.errors.append(
            "after rejoin node 1's frontier read is not the latest version"
        )


SCENARIOS: Dict[str, Scenario] = {
    s.name: s
    for s in [
        Scenario("publish_vs_shared_fill",
                 _build_publish_vs_fill, _actors_publish_vs_fill),
        Scenario("gc_vs_pin", _build_gc_vs_pin, _actors_gc_vs_pin),
        Scenario("gc_vs_cached_read",
                 _build_gc_vs_cached_read, _actors_gc_vs_cached_read),
        Scenario("write_async_vs_flush",
                 _build_write_async_vs_flush, _actors_write_async_vs_flush,
                 finalize=_finalize_write_async_vs_flush),
        Scenario("warmer_vs_demand_read",
                 _build_warmer_vs_demand, _actors_warmer_vs_demand),
        Scenario("shard_failover_vs_publish",
                 _build_shard_failover_vs_publish,
                 _actors_shard_failover_vs_publish,
                 finalize=_finalize_shard_failover_vs_publish),
        Scenario("node_death_vs_gc_ack",
                 _build_node_death_vs_gc_ack,
                 _actors_node_death_vs_gc_ack,
                 finalize=_finalize_node_death_vs_gc_ack),
    ]
}


def run_all(max_schedules: int = 512) -> List[Report]:
    """Explore every registered scenario; returns one report per scenario."""
    return [explore(s, max_schedules) for s in SCENARIOS.values()]


if __name__ == "__main__":  # pragma: no cover - manual driver
    bad = False
    for report in run_all():
        print(report)
        for failure in report.failures:
            bad = True
            print(f"  {failure}")
    raise SystemExit(1 if bad else 0)
