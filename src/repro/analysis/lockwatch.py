"""Runtime lock-order watchdog (lockdep-lite).

Opt-in via ``REPRO_LOCKWATCH=1``. When enabled, :func:`make_lock` returns an
instrumented :class:`WatchedLock` that threads a per-thread acquisition stack
through every ``core/`` lock and feeds a process-global *name-based*
acquisition graph (one node per lock CLASS, e.g. ``PageCache._lock``, not per
instance — like the kernel's lockdep, one bad nesting anywhere proves the
discipline broken everywhere). On each blocking acquisition the watchdog:

* records an edge ``held → acquiring`` for every lock currently held,
* checks the declared partial order (:mod:`repro.analysis.lock_order`) and
  flags out-of-order edges immediately,
* runs an eager cycle check over the blocking-edge graph — an ABBA pattern is
  reported the moment the second ordering appears, even if the two nestings
  happened in different tests, on different threads, minutes apart, and never
  actually deadlocked.

Try-lock acquisitions (``blocking=False`` / ``timeout=0``) are recorded for
diagnostics but excluded from cycle detection: a trylock cannot deadlock, and
``ReplicaBalancer.rebalance`` leans on exactly that.

:func:`install_blocking_hooks` additionally patches ``Future.result``,
``Future.exception`` and ``Thread.join`` so that *waiting on other work while
holding a non-blocking-class lock* (the cross-pool join-under-lock bug family)
is reported with the offending lock names.

When ``REPRO_LOCKWATCH`` is unset, :func:`make_lock` returns a plain
``threading.Lock()`` — the identical object production code would have
constructed inline, so the disabled path is zero-overhead by construction
(``test_analysis.py`` asserts the class identity; the bench smoke row in the
PR description shows the measured overhead is noise).
"""

from __future__ import annotations

import dataclasses
import os
import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import lock_order

ENV_VAR = "REPRO_LOCKWATCH"


def enabled() -> bool:
    """True when the watchdog is switched on for this process."""
    return os.environ.get(ENV_VAR, "") not in ("", "0")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str  #: "lock-order" | "lock-cycle" | "join-under-lock" | ...
    message: str
    thread: str
    held: Tuple[str, ...]

    def __str__(self) -> str:
        held = " -> ".join(self.held) if self.held else "(none)"
        return f"[{self.rule}] {self.message} (thread={self.thread}, held: {held})"


class LockWatch:
    """The acquisition-graph recorder. One process-global instance backs
    :func:`make_lock`; tests build private instances to seed violations
    without polluting the global graph."""

    def __init__(self) -> None:
        self._mu = threading.Lock()  # guards the graph + violation list only
        self._tls = threading.local()
        #: blocking acquisition edges held -> {acquiring}; cycle-checked
        self.blocking_edges: Dict[str, Set[str]] = {}
        #: try-lock edges; diagnostics only, never deadlock
        self.try_edges: Dict[str, Set[str]] = {}
        self.violations: List[Violation] = []
        self.names_seen: Set[str] = set()

    # -- per-thread stack ---------------------------------------------------
    def _stack(self) -> List[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def held(self) -> Tuple[str, ...]:
        return tuple(self._stack())

    # -- event hooks (called by WatchedLock) --------------------------------
    def before_blocking_acquire(self, name: str) -> None:
        stack = self._stack()
        if name in stack:
            self._record(
                "lock-cycle",
                f"re-acquiring {name} already held by this thread "
                f"(non-reentrant: guaranteed self-deadlock)",
            )
            return
        if not stack:
            return
        for held in stack:
            reason = lock_order.order_violation(held, name)
            if reason is not None:
                self._record("lock-order", reason)
        with self._mu:
            new_edge = False
            for held in stack:
                targets = self.blocking_edges.setdefault(held, set())
                if name not in targets:
                    targets.add(name)
                    new_edge = True
            if new_edge:
                cycle = self._find_cycle_locked(name, stack)
        if new_edge and cycle is not None:
            self._record(
                "lock-cycle",
                "acquisition graph contains a cycle (potential deadlock): "
                + " -> ".join(cycle),
            )

    def _find_cycle_locked(
        self, start: str, held: List[str]
    ) -> Optional[List[str]]:
        """DFS from ``start`` over blocking edges; a path back to any held
        lock closes a cycle with the edges just added."""
        held_set = set(held)
        path: List[str] = [start]
        seen: Set[str] = set()

        def dfs(node: str) -> Optional[List[str]]:
            for nxt in self.blocking_edges.get(node, ()):
                if nxt in held_set:
                    return path + [nxt]
                if nxt in seen:
                    continue
                seen.add(nxt)
                path.append(nxt)
                found = dfs(nxt)
                if found is not None:
                    return found
                path.pop()
            return None

        return dfs(start)

    def on_acquired(self, name: str, blocking: bool) -> None:
        if not blocking:
            stack = self._stack()
            with self._mu:
                for held in stack:
                    self.try_edges.setdefault(held, set()).add(name)
        self.names_seen.add(name)
        self._stack().append(name)

    def on_released(self, name: str) -> None:
        stack = self._stack()
        # pop the most recent occurrence: condition-variable wait releases
        # the aliased lock from mid-stack-looking positions legitimately
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return

    # -- blocking-call check (used by the installed hooks) ------------------
    def check_blocking_call(self, what: str) -> None:
        offenders = [
            n for n in self._stack() if not lock_order.allows_blocking(n)
        ]
        if offenders:
            self._record(
                "join-under-lock",
                f"{what} while holding {', '.join(offenders)} — waiting on "
                f"other work under a non-blocking-class lock can deadlock "
                f"when that work needs the same lock",
            )

    def _record(self, rule: str, message: str) -> None:
        v = Violation(
            rule, message, threading.current_thread().name, self.held()
        )
        with self._mu:
            self.violations.append(v)

    # -- test-suite interface ------------------------------------------------
    def assert_clean(self, reset: bool = True) -> None:
        with self._mu:
            found, self.violations = self.violations, (
                [] if reset else self.violations
            )
        if found:
            raise AssertionError(
                "lockwatch recorded %d violation(s):\n%s"
                % (len(found), "\n".join(f"  {v}" for v in found))
            )


class WatchedLock:
    """Drop-in ``threading.Lock`` replacement reporting to a LockWatch.

    Exposes exactly the protocol ``threading.Condition`` needs from a raw
    lock — ``acquire(blocking, timeout)`` / ``release`` / ``locked`` — so
    conditions built over a WatchedLock keep the acquisition stack truthful
    across ``wait()`` (the release inside wait pops, the re-acquire pushes).
    """

    __slots__ = ("name", "_lock", "_watch")

    def __init__(self, name: str, watch: LockWatch) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._watch = watch

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        is_blocking = bool(blocking) and timeout != 0
        if is_blocking:
            self._watch.before_blocking_acquire(self.name)
            got = self._lock.acquire(True, timeout)
        else:
            got = self._lock.acquire(False)
        if got:
            self._watch.on_acquired(self.name, is_blocking)
        return got

    def release(self) -> None:
        self._lock.release()
        self._watch.on_released(self.name)

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "WatchedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def __repr__(self) -> str:
        return f"<WatchedLock {self.name} locked={self._lock.locked()}>"


# -- the process-global watch + factory -------------------------------------

_WATCH: Optional[LockWatch] = None
_WATCH_MU = threading.Lock()


def watch() -> LockWatch:
    """The process-global LockWatch (created on first use)."""
    global _WATCH
    if _WATCH is None:
        with _WATCH_MU:
            if _WATCH is None:
                _WATCH = LockWatch()
    return _WATCH


def make_lock(name: str) -> threading.Lock:
    """Lock factory every ``core/`` lock construction goes through.

    Disabled (default): returns a plain ``threading.Lock()`` — byte-for-byte
    the object the code would otherwise construct inline; zero overhead.
    Enabled: returns a :class:`WatchedLock` wired to the global watch. The
    ``name`` must appear in :data:`repro.analysis.lock_order.LOCKS`; an
    undeclared name is itself recorded as a violation.
    """
    if not enabled():
        return threading.Lock()
    w = watch()
    if lock_order.get(name) is None:
        w._record(
            "undeclared-lock",
            f"make_lock({name!r}): lock not declared in "
            f"analysis/lock_order.py — add it to the hierarchy",
        )
    return WatchedLock(name, w)


def make_condition(
    name: str, lock: Optional[object] = None
) -> threading.Condition:
    """Condition factory. With ``lock`` given, wraps it (the condition then
    aliases that lock's name in the acquisition graph — declare the alias in
    lock_order, e.g. ``VersionManager._published_cv``). Without, builds the
    condition over its own lock (watched under ``name`` when enabled)."""
    if not enabled():
        return threading.Condition(lock)
    if lock is None:
        w = watch()
        if lock_order.get(name) is None:
            w._record(
                "undeclared-lock",
                f"make_condition({name!r}): lock not declared in "
                f"analysis/lock_order.py — add it to the hierarchy",
            )
        lock = WatchedLock(name, w)
    return threading.Condition(lock)


# -- join-under-lock hooks ---------------------------------------------------

_HOOKS: Optional[Tuple[object, object, object]] = None


def install_blocking_hooks(target: Optional[LockWatch] = None) -> None:
    """Patch ``Future.result`` / ``Future.exception`` / ``Thread.join`` to
    report waits performed while holding a non-blocking-class lock. Calls
    that provably cannot block (future already done; ``join(timeout=0)``;
    dead thread) are exempt. Idempotent; undo with
    :func:`remove_blocking_hooks`."""
    global _HOOKS
    if _HOOKS is not None:
        return
    w = target if target is not None else watch()
    orig_result = Future.result
    orig_exception = Future.exception
    orig_join = threading.Thread.join

    def patched_result(self, timeout=None):
        if not self.done():
            w.check_blocking_call("Future.result()")
        return orig_result(self, timeout)

    def patched_exception(self, timeout=None):
        if not self.done():
            w.check_blocking_call("Future.exception()")
        return orig_exception(self, timeout)

    def patched_join(self, timeout=None):
        if timeout != 0 and self.is_alive():
            w.check_blocking_call(f"Thread.join({self.name})")
        return orig_join(self, timeout)

    Future.result = patched_result
    Future.exception = patched_exception
    threading.Thread.join = patched_join
    _HOOKS = (orig_result, orig_exception, orig_join)


def remove_blocking_hooks() -> None:
    global _HOOKS
    if _HOOKS is None:
        return
    orig_result, orig_exception, orig_join = _HOOKS
    Future.result = orig_result
    Future.exception = orig_exception
    threading.Thread.join = orig_join
    _HOOKS = None
