"""Concurrency correctness toolkit.

Three cooperating checkers for the repo's lock-free design:

* :mod:`repro.analysis.lock_order` — the declared global lock hierarchy.
* :mod:`repro.analysis.lockwatch` — opt-in runtime watchdog
  (``REPRO_LOCKWATCH=1``): acquisition-graph recording, cycle detection,
  join-under-lock hooks. Zero overhead when disabled.
* :mod:`repro.analysis.lint` — static AST lint enforcing the hierarchy,
  the no-blocking-under-lock rule and the forbidden-API rules
  (``tools/lint_concurrency.py`` is the CLI).
* :mod:`repro.analysis.schedules` — deterministic interleaving explorer
  asserting the coherence invariant over every bounded schedule of the
  hairiest operation pairs.

This package must stay import-light: ``core/`` imports ``lockwatch`` at
module load, so nothing here may import ``repro.core`` at the top level
(``schedules`` imports it lazily inside its builders).
"""

from repro.analysis import lock_order  # noqa: F401
from repro.analysis.lockwatch import (  # noqa: F401
    enabled,
    make_condition,
    make_lock,
    watch,
)
