"""Static lock-discipline lint (AST pass, no execution).

Parses every ``.py`` file under the given roots, builds a per-class lock
model (which ``self._x`` attributes are locks and which declared name each
carries), then walks every function tracking the set of locks held at each
statement — ``with`` regions plus the ``if not lock.acquire(): return``
try-lock idiom — and reports:

``blocking-under-lock``
    A blocking call (``time.sleep``, ``Future.result``/``.exception``,
    ``.wait``/``.wait_for``, ``.join``, ``.shutdown``, or one of the
    modeled-RTT RPC methods) inside the critical section of a lock whose
    :class:`~repro.analysis.lock_order.LockSpec` does not set
    ``allow_blocking``. Calls to repo methods that *transitively* block are
    flagged too (method summaries are propagated to a fixpoint over the
    resolvable call graph). Waiting on a condition you hold is legal and
    exempted.

``lock-order``
    An acquisition edge (direct ``with`` nesting, the acquire idiom, or a
    call into a method whose summary acquires locks) that violates the
    declared hierarchy in :mod:`repro.analysis.lock_order` — downward edges
    and same-level nesting.

``undeclared-lock``
    ``make_lock``/``make_condition`` with a non-literal name or a name
    missing from the registry: growing the concurrency surface requires
    declaring where the new lock sits in the order.

``raw-lock``
    Direct ``threading.Lock()``/``RLock()``/``Condition()`` construction in
    ``core``/``storage`` instead of the instrumentable factory.

``facade-import``
    An internal import of the deprecated ``BlobStore`` facade
    (``repro.core.blob``) — only the facade module itself and the package
    ``__init__`` re-export may reference it.

``fulfill-without-plan``
    A ``PageCache.fulfill(...)`` call in a function that never calls
    ``.plan(...)``: fills must go through the single-flight plan protocol or
    they race admission and double-fetch suppression.

``direct-store-mutation``
    Mutation of another object's ``_pages``/``_nodes``/``_lru``/``_store``
    private maps — provider and shard state may only change through their
    own (locked) methods.

Suppression: append ``# lint: allow(rule-name)`` to the offending line, or
put ``# lint: skip-file`` anywhere in a file to exempt it entirely. The
analysis is deliberately under-approximate where Python is dynamic (calls
through ambiguous or generic method names are not resolved); the runtime
watchdog covers what static resolution cannot see.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis import lock_order

__all__ = ["LintViolation", "lint_paths", "lint_files", "RULES"]

RULES = (
    "blocking-under-lock",
    "lock-order",
    "undeclared-lock",
    "raw-lock",
    "facade-import",
    "fulfill-without-plan",
    "direct-store-mutation",
)

#: attribute names whose call is (potentially) blocking on any receiver
_BLOCKING_ATTRS = {"result", "exception", "wait", "wait_for", "join", "shutdown", "sleep"}
#: repo methods that model a network round trip or provider service time
_RPC_METHODS = {
    "put_nodes", "get_node", "get_nodes", "get_page", "get_pages",
    "put_pages", "delete_pages", "delete_nodes", "_round_trip", "_serve",
}
#: method names too generic to resolve through a non-``self`` receiver
_GENERIC_NAMES = {
    "get", "put", "open", "read", "write", "close", "wait", "join", "submit",
    "result", "exception", "release", "acquire", "next", "stop", "clear",
    "flush", "gc", "record", "reset", "set", "update", "pop", "append", "add",
    "extend", "remove", "discard", "items", "keys", "values", "copy", "view",
    "start", "run", "send", "create", "alloc", "done", "cancel",
}
#: ``with``-item attribute suffixes treated as locks even when unregistered
_LOCKISH_RE = re.compile(r"(_lock|_cv|_guard|_mutex|_sem)$|lock")
_STORE_ATTRS = {"_pages", "_nodes", "_lru", "_store"}
_STORE_MUTATORS = {"pop", "clear", "update", "setdefault", "append", "extend",
                   "popitem", "insert", "remove", "add"}
_RAW_LOCK_CTORS = {"threading.Lock", "threading.RLock", "threading.Condition"}

_PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\(([a-z\-,\s]+)\)")
_SKIP_FILE_RE = re.compile(r"#\s*lint:\s*skip-file")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class _FuncInfo:
    """One function/method plus its summary for the transitive fixpoint."""

    key: str  # "relpath::Class.method" — globally unique
    simple: str
    cls: Optional[str]
    node: ast.AST
    path: str
    lock_map: Dict[str, str]  # self attr -> canonical lock name (its class)
    class_methods: Dict[str, "_FuncInfo"] = dataclasses.field(default_factory=dict)
    direct_blocking: bool = False
    direct_acquired: Set[str] = dataclasses.field(default_factory=set)
    callee_keys: Set[str] = dataclasses.field(default_factory=set)
    blocking: bool = False
    acquired: Set[str] = dataclasses.field(default_factory=set)


@dataclasses.dataclass(frozen=True)
class _Held:
    name: str      # canonical (or synthesized) lock name
    recv: str      # source text of the acquiring expression, for cond-wait
    known: bool    # whether the name is in the registry


def _allows_blocking(held: _Held) -> bool:
    return held.known and lock_order.allows_blocking(held.name)


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is total on parsed trees
        return "<expr>"


def _is_self(node: ast.AST) -> bool:
    return isinstance(node, ast.Name) and node.id == "self"


class _Linter:
    def __init__(self) -> None:
        self.violations: List[LintViolation] = []
        self._seen: Set[Tuple[str, int, str]] = set()
        self.funcs: Dict[str, _FuncInfo] = {}
        self.by_simple: Dict[str, List[_FuncInfo]] = {}
        self._pragmas: Dict[str, Dict[int, Set[str]]] = {}
        self._modules: List[Tuple[str, ast.Module]] = []

    # -- driver -----------------------------------------------------------
    def run(self, files: Sequence[str]) -> List[LintViolation]:
        for path in files:
            self._load(path)
        self._fixpoint()
        for path, tree in self._modules:
            self._check_module(path, tree)
        for info in self.funcs.values():
            self._check_function(info)
        self.violations.sort(key=lambda v: (v.path, v.line, v.rule))
        return self.violations

    def _report(self, path: str, line: int, rule: str, message: str) -> None:
        if rule in self._pragmas.get(path, {}).get(line, set()):
            return
        key = (path, line, rule)
        if key in self._seen:
            return
        self._seen.add(key)
        self.violations.append(LintViolation(path, line, rule, message))

    # -- load: parse, pragma table, lock maps, function index -------------
    def _load(self, path: str) -> None:
        try:
            with open(path, "r", encoding="utf-8") as fh:
                source = fh.read()
        except OSError:
            return
        if _SKIP_FILE_RE.search(source):
            return
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as exc:
            self._report(path, exc.lineno or 1, "raw-lock",
                         f"file does not parse: {exc.msg}")
            return
        pragmas: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(source.splitlines(), start=1):
            m = _PRAGMA_RE.search(line)
            if m:
                pragmas[lineno] = {r.strip() for r in m.group(1).split(",")}
        self._pragmas[path] = pragmas
        self._modules.append((path, tree))
        self._index_module(path, tree)

    def _index_module(self, path: str, tree: ast.Module) -> None:
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                lock_map = self._class_lock_map(path, node)
                methods: Dict[str, _FuncInfo] = {}
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        info = _FuncInfo(
                            key=f"{path}::{node.name}.{item.name}",
                            simple=item.name, cls=node.name, node=item,
                            path=path, lock_map=lock_map,
                        )
                        methods[item.name] = info
                for info in methods.values():
                    info.class_methods = methods
                    self.funcs[info.key] = info
                    self.by_simple.setdefault(info.simple, []).append(info)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = _FuncInfo(
                    key=f"{path}::{node.name}", simple=node.name, cls=None,
                    node=node, path=path, lock_map={},
                )
                self.funcs[info.key] = info
                self.by_simple.setdefault(info.simple, []).append(info)

    def _class_lock_map(self, path: str, cls: ast.ClassDef) -> Dict[str, str]:
        """attr -> canonical lock name, from factory calls and raw ctors."""
        lock_map: Dict[str, str] = {}

        def factory_name(call: ast.Call) -> Optional[str]:
            fn = call.func
            fname = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else "")
            if fname not in ("make_lock", "make_condition"):
                return None
            if call.args and isinstance(call.args[0], ast.Constant) \
                    and isinstance(call.args[0].value, str):
                name = call.args[0].value
                if name not in lock_order.BY_NAME \
                        and not path.endswith("lockwatch.py"):
                    self._report(
                        path, call.lineno, "undeclared-lock",
                        f"{fname}({name!r}): name not declared in "
                        f"repro.analysis.lock_order — add a LockSpec with "
                        f"its level before using it")
                return name
            if not path.endswith("lockwatch.py"):
                self._report(
                    path, call.lineno, "undeclared-lock",
                    f"{fname}() needs a string-literal lock name so the "
                    f"lint and watchdog can resolve it")
            return None

        def record(attr: str, value: ast.AST) -> None:
            if not isinstance(value, ast.Call):
                return
            name = factory_name(value)
            if name is not None:
                lock_map[attr] = name
            elif _unparse(value.func) in _RAW_LOCK_CTORS:
                lock_map[attr] = f"{cls.name}.{attr}"  # unregistered: strict

        for item in ast.walk(cls):
            if isinstance(item, ast.Assign):
                for tgt in item.targets:
                    if isinstance(tgt, ast.Attribute) and _is_self(tgt.value):
                        record(tgt.attr, item.value)
                    elif isinstance(tgt, ast.Name):
                        record(tgt.id, item.value)
            elif isinstance(item, ast.AnnAssign) and item.value is not None:
                # dataclass field(default_factory=lambda: make_lock("..."))
                tgt = item.target
                attr = tgt.id if isinstance(tgt, ast.Name) else (
                    tgt.attr if isinstance(tgt, ast.Attribute) else None)
                if attr is None:
                    continue
                record(attr, item.value)
                if isinstance(item.value, ast.Call):
                    for kw in item.value.keywords:
                        if kw.arg == "default_factory" \
                                and isinstance(kw.value, ast.Lambda) \
                                and isinstance(kw.value.body, ast.Call):
                            record(attr, kw.value.body)
        return lock_map

    # -- module-level rules -----------------------------------------------
    def _check_module(self, path: str, tree: ast.Module) -> None:
        norm = path.replace(os.sep, "/")
        in_core = "/core/" in norm or "/storage/" in norm
        facade_exempt = norm.endswith(("core/blob.py", "core/__init__.py"))
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and not facade_exempt:
                mod = node.module or ""
                if mod.endswith("core.blob"):
                    self._report(path, node.lineno, "facade-import",
                                 "internal import of the deprecated BlobStore "
                                 "facade (repro.core.blob) — use Cluster/"
                                 "Session/BlobHandle")
                elif mod.endswith("repro.core") and any(
                        a.name == "BlobStore" for a in node.names):
                    self._report(path, node.lineno, "facade-import",
                                 "importing BlobStore from repro.core — the "
                                 "facade is for external callers only")
            elif isinstance(node, ast.Import) and not facade_exempt:
                for alias in node.names:
                    if alias.name.endswith("core.blob"):
                        self._report(path, node.lineno, "facade-import",
                                     "internal import of the deprecated "
                                     "BlobStore facade (repro.core.blob)")
            elif isinstance(node, ast.Call) and in_core:
                if _unparse(node.func) in _RAW_LOCK_CTORS:
                    self._report(path, node.lineno, "raw-lock",
                                 f"direct {_unparse(node.func)}() in core/"
                                 f"storage — construct locks via repro."
                                 f"analysis.lockwatch.make_lock/make_condition"
                                 f" so the watchdog can instrument them")
                for kw in node.keywords:
                    if _unparse(kw.value) in _RAW_LOCK_CTORS:
                        self._report(path, node.lineno, "raw-lock",
                                     f"{_unparse(kw.value)} passed as a "
                                     f"factory — use the lockwatch factory")
            self._check_store_mutation(path, node)
        self._check_fulfill_plan(path, tree)

    def _check_store_mutation(self, path: str, node: ast.AST) -> None:
        def foreign_store(expr: ast.AST) -> Optional[str]:
            if isinstance(expr, ast.Attribute) and expr.attr in _STORE_ATTRS \
                    and not _is_self(expr.value):
                return f"{_unparse(expr.value)}.{expr.attr}"
            return None

        targets: List[ast.AST] = []
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
        for tgt in targets:
            if isinstance(tgt, ast.Subscript):
                store = foreign_store(tgt.value)
                if store:
                    self._report(path, tgt.lineno, "direct-store-mutation",
                                 f"mutates {store} directly — go through the "
                                 f"owner's locked methods")
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _STORE_MUTATORS:
            store = foreign_store(node.func.value)
            if store:
                self._report(path, node.lineno, "direct-store-mutation",
                             f"calls {store}.{node.func.attr}(...) directly — "
                             f"go through the owner's locked methods")

    def _check_fulfill_plan(self, path: str, tree: ast.Module) -> None:
        if path.replace(os.sep, "/").endswith("core/page_cache.py"):
            return  # the cache's own implementation
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            fulfills = [
                c for c in ast.walk(node)
                if isinstance(c, ast.Call) and isinstance(c.func, ast.Attribute)
                and c.func.attr == "fulfill"
            ]
            if not fulfills:
                continue
            has_plan = any(
                isinstance(c, ast.Call) and isinstance(c.func, ast.Attribute)
                and c.func.attr == "plan"
                for c in ast.walk(node)
            )
            if not has_plan:
                for c in fulfills:
                    self._report(path, c.lineno, "fulfill-without-plan",
                                 "cache fill bypasses PageCache.plan() — "
                                 "fills must go through the single-flight "
                                 "plan/fulfill protocol")

    # -- call resolution ---------------------------------------------------
    def _resolve_call(self, call: ast.Call, ctx: _FuncInfo) -> Optional[_FuncInfo]:
        fn = call.func
        if isinstance(fn, ast.Attribute):
            name = fn.attr
            if _is_self(fn.value) and name in ctx.class_methods:
                return ctx.class_methods[name]
            if name in _GENERIC_NAMES:
                return None
            cands = self.by_simple.get(name, [])
            return cands[0] if len(cands) == 1 else None
        if isinstance(fn, ast.Name):
            if fn.id in _GENERIC_NAMES:
                return None
            cands = self.by_simple.get(fn.id, [])
            if len(cands) == 1 and cands[0].cls is None:
                return cands[0]
        return None

    def _lock_from_attr(self, expr: ast.Attribute, ctx: _FuncInfo) -> Optional[_Held]:
        attr, recv = expr.attr, _unparse(expr)
        if _is_self(expr.value) and attr in ctx.lock_map:
            name = ctx.lock_map[attr]
            return _Held(name, recv, name in lock_order.BY_NAME)
        spec = lock_order.BY_UNIQUE_ATTR.get(attr)
        if spec is not None:
            return _Held(spec.name, recv, True)
        if _LOCKISH_RE.search(attr):
            owner = ctx.cls or "<module>"
            return _Held(f"{owner}.{attr}", recv, False)
        return None

    def _locks_from_with_item(self, expr: ast.AST, ctx: _FuncInfo) -> List[_Held]:
        if isinstance(expr, ast.Attribute):
            held = self._lock_from_attr(expr, ctx)
            return [held] if held else []
        if isinstance(expr, ast.Call):
            callee = self._resolve_call(expr, ctx)
            if callee is not None and callee.acquired:
                recv = _unparse(expr)
                return [
                    _Held(name, recv, name in lock_order.BY_NAME)
                    for name in sorted(callee.acquired)
                ]
        return []

    # -- summary pass -------------------------------------------------------
    def _summarize(self) -> None:
        for info in self.funcs.values():
            for node in ast.walk(info.node):
                if isinstance(node, ast.Call):
                    if self._blocking_call_kind(node) is not None:
                        info.direct_blocking = True
                    callee = self._resolve_call(node, info)
                    if callee is not None and callee.key != info.key:
                        info.callee_keys.add(callee.key)
                    fn = node.func
                    if isinstance(fn, ast.Attribute) and fn.attr == "acquire" \
                            and isinstance(fn.value, ast.Attribute):
                        held = self._lock_from_attr(fn.value, info)
                        if held:
                            info.direct_acquired.add(held.name)
                elif isinstance(node, (ast.With, ast.AsyncWith)):
                    for item in node.items:
                        if isinstance(item.context_expr, ast.Attribute):
                            held = self._lock_from_attr(item.context_expr, info)
                            if held:
                                info.direct_acquired.add(held.name)

    def _fixpoint(self) -> None:
        self._summarize()
        changed = True
        while changed:
            changed = False
            for info in self.funcs.values():
                blocking = info.direct_blocking
                acquired = set(info.direct_acquired)
                for key in info.callee_keys:
                    callee = self.funcs.get(key)
                    if callee is None:
                        continue
                    blocking = blocking or callee.blocking
                    acquired |= callee.acquired
                if blocking != info.blocking or acquired != info.acquired:
                    info.blocking, info.acquired = blocking, acquired
                    changed = True

    # -- blocking-call classification ---------------------------------------
    def _blocking_call_kind(self, call: ast.Call) -> Optional[str]:
        """A short description if this call blocks, else None."""
        fn = call.func
        if not isinstance(fn, ast.Attribute):
            return None
        attr = fn.attr
        if attr in _RPC_METHODS:
            return f"modeled-RTT RPC .{attr}()"
        if attr not in _BLOCKING_ATTRS:
            return None
        recv = fn.value
        if attr == "join":
            # str.join / os.path.join are pure; timeout=0 polls, not blocks
            if isinstance(recv, ast.Constant):
                return None
            if _unparse(recv).endswith("path"):
                return None
            for kw in call.keywords:
                if kw.arg == "timeout" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value == 0:
                    return None
        if attr == "shutdown":
            for kw in call.keywords:
                if kw.arg == "wait" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is False:
                    return None
        if attr in ("result", "exception"):
            for kw in call.keywords:
                if kw.arg == "timeout" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value == 0:
                    return None
        return f"blocking .{attr}()"

    # -- region-tracked checking pass ----------------------------------------
    def _check_function(self, info: _FuncInfo) -> None:
        body = getattr(info.node, "body", [])
        self._process_block(body, [], info)

    def _order_check(self, held: List[_Held], new: _Held, line: int,
                     info: _FuncInfo, via: str = "") -> None:
        for h in held:
            reason = lock_order.order_violation(h.name, new.name)
            if reason:
                self._report(info.path, line, "lock-order", reason + via)

    def _blocking_check(self, held: List[_Held], line: int, info: _FuncInfo,
                        desc: str) -> None:
        offenders = [h.name for h in held if not _allows_blocking(h)]
        if offenders:
            self._report(
                info.path, line, "blocking-under-lock",
                f"{desc} while holding {', '.join(offenders)} — move the "
                f"blocking work outside the critical section or declare the "
                f"lock allow_blocking in lock_order")

    def _scan_events(self, node: ast.AST, held: List[_Held],
                     info: _FuncInfo) -> None:
        """Check every call in an expression/simple statement against the
        currently held set, for both blocking and transitive order edges."""
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            kind = self._blocking_call_kind(sub)
            if kind is not None and held:
                fn = sub.func
                if isinstance(fn, ast.Attribute) \
                        and fn.attr in ("wait", "wait_for") \
                        and any(_unparse(fn.value) == h.recv for h in held):
                    kind = None  # waiting on a condition we hold is the point
                if kind is not None:
                    self._blocking_check(held, sub.lineno, info, kind)
            callee = self._resolve_call(sub, info)
            if callee is None:
                continue
            if held and callee.blocking and self._blocking_call_kind(sub) is None:
                self._blocking_check(
                    held, sub.lineno, info,
                    f"call to {callee.simple}() which blocks (transitively)")
            for name in sorted(callee.acquired):
                new = _Held(name, _unparse(sub), name in lock_order.BY_NAME)
                self._order_check(held, new, sub.lineno, info,
                                  via=f" (via {callee.simple}())")

    def _acquire_idiom(self, stmt: ast.stmt, held: List[_Held],
                       info: _FuncInfo) -> List[_Held]:
        """Locks this statement acquires for the REST of the current block:
        ``x.acquire(...)`` expression statements and the
        ``if not x.acquire(blocking=False): return`` try-lock guard."""
        call: Optional[ast.Call] = None
        guarded = False
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
        elif isinstance(stmt, ast.If) and isinstance(stmt.test, ast.UnaryOp) \
                and isinstance(stmt.test.op, ast.Not) \
                and isinstance(stmt.test.operand, ast.Call):
            bails = (ast.Return, ast.Raise, ast.Continue, ast.Break)
            if stmt.body and isinstance(stmt.body[-1], bails):
                call = stmt.test.operand
                guarded = True
        if call is None or not isinstance(call.func, ast.Attribute) \
                or call.func.attr != "acquire" \
                or not isinstance(call.func.value, ast.Attribute):
            return []
        lock = self._lock_from_attr(call.func.value, info)
        if lock is None:
            return []
        trylock = guarded or any(
            kw.arg == "blocking" and isinstance(kw.value, ast.Constant)
            and not kw.value.value for kw in call.keywords
        ) or (call.args and isinstance(call.args[0], ast.Constant)
              and not call.args[0].value)
        if not trylock:
            self._order_check(held, lock, stmt.lineno, info)
        return [lock]

    def _release_names(self, stmt: ast.stmt, info: _FuncInfo) -> List[str]:
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            fn = stmt.value.func
            if isinstance(fn, ast.Attribute) and fn.attr == "release" \
                    and isinstance(fn.value, ast.Attribute):
                lock = self._lock_from_attr(fn.value, info)
                if lock is not None:
                    return [lock.name]
        return []

    def _process_block(self, stmts: Sequence[ast.stmt], held: List[_Held],
                       info: _FuncInfo) -> None:
        held = list(held)
        for stmt in stmts:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                new: List[_Held] = []
                for item in stmt.items:
                    self._scan_events(item.context_expr, held, info)
                    for lock in self._locks_from_with_item(item.context_expr,
                                                           info):
                        self._order_check(held + new, lock, stmt.lineno, info)
                        new.append(lock)
                self._process_block(stmt.body, held + new, info)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested helpers usually run inside the enclosing region —
                # treat them as if inlined (conservative)
                self._process_block(stmt.body, held, info)
            elif isinstance(stmt, ast.ClassDef):
                continue
            elif isinstance(stmt, (ast.If, ast.While)):
                self._scan_events(stmt.test, held, info)
                acquired = self._acquire_idiom(stmt, held, info)
                self._process_block(stmt.body, held, info)
                self._process_block(stmt.orelse, held, info)
                held.extend(acquired)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._scan_events(stmt.iter, held, info)
                self._process_block(stmt.body, held, info)
                self._process_block(stmt.orelse, held, info)
            elif isinstance(stmt, ast.Try):
                self._process_block(stmt.body, held, info)
                for handler in stmt.handlers:
                    self._process_block(handler.body, held, info)
                self._process_block(stmt.orelse, held, info)
                self._process_block(stmt.finalbody, held, info)
            else:
                self._scan_events(stmt, held, info)
                for lock in self._acquire_idiom(stmt, held, info):
                    held.append(lock)
                for name in self._release_names(stmt, info):
                    for i in range(len(held) - 1, -1, -1):
                        if held[i].name == name:
                            del held[i]
                            break


def _collect_files(paths: Iterable[str]) -> List[str]:
    files: List[str] = []
    for path in paths:
        if os.path.isdir(path):
            for root, _dirs, names in os.walk(path):
                if "__pycache__" in root:
                    continue
                for name in sorted(names):
                    if name.endswith(".py"):
                        files.append(os.path.join(root, name))
        elif path.endswith(".py"):
            files.append(path)
    return sorted(set(files))


def lint_files(files: Sequence[str]) -> List[LintViolation]:
    """Lint an explicit list of Python files together (one call graph)."""
    return _Linter().run(list(files))


def lint_paths(paths: Sequence[str]) -> List[LintViolation]:
    """Recursively lint every ``.py`` under ``paths``; returns violations
    sorted by location. An empty list means the tree is clean."""
    return lint_files(_collect_files(paths))
