from repro.data.pipeline import PipelineConfig, TokenPipeline, write_token_corpus
from repro.data.sky import SkyLayout, SkySimulator, detect_transients

__all__ = ["PipelineConfig", "TokenPipeline", "write_token_corpus",
           "SkyLayout", "SkySimulator", "detect_transients"]
