"""Synthetic telescope sky (the paper's application, §I).

The sky is a grid of ``region`` images concatenated into one global blob (the
paper's "very long string of bytes obtained by concatenating the images in
binary form"). Each observation epoch produces a new *version* of the blob:
regions are re-imaged with photon noise, and occasionally a supernova ignites
— a transient brightness spike following a simple light curve.

``SkySimulator.observe_epoch`` WRITEs the updated regions (fine-grain patches,
one per region — concurrent telescope writers are threads); detection code
READs two versions of a region and difference-images them.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import BlobHandle, Session


@dataclasses.dataclass(frozen=True)
class SkyLayout:
    n_regions: int = 64
    region_px: int = 64  # region is region_px × region_px float32 pixels
    page_size: int = 4096

    @property
    def region_bytes(self) -> int:
        raw = self.region_px * self.region_px * 4
        return -(-raw // self.page_size) * self.page_size  # page-aligned

    @property
    def blob_bytes(self) -> int:
        total = self.n_regions * self.region_bytes
        return 1 << (total - 1).bit_length()  # power of two (paper §II)


@dataclasses.dataclass
class Supernova:
    region: int
    x: int
    y: int
    ignite_epoch: int
    peak: float


class SkySimulator:
    """Generates epochs of the sky into the blob store through one writer
    :class:`Session` (the telescope client)."""

    def __init__(self, session: Session, layout: SkyLayout = SkyLayout(), seed: int = 0,
                 sn_rate: float = 0.05) -> None:
        self.session = session
        self.layout = layout
        self.rng = np.random.default_rng(seed)
        self.sn_rate = sn_rate
        self.handle: BlobHandle = session.create(layout.blob_bytes, layout.page_size)
        self.blob_id = self.handle.blob_id
        # static star field per region
        self._stars: List[np.ndarray] = [
            self._star_field() for _ in range(layout.n_regions)
        ]
        self.supernovae: List[Supernova] = []
        self.epoch = 0

    def _star_field(self) -> np.ndarray:
        px = self.layout.region_px
        img = np.zeros((px, px), np.float32)
        n_stars = int(self.rng.integers(8, 24))
        xs = self.rng.integers(0, px, n_stars)
        ys = self.rng.integers(0, px, n_stars)
        mag = self.rng.uniform(50, 400, n_stars).astype(np.float32)
        img[ys, xs] = mag
        return img

    def _light_curve(self, sn: Supernova, epoch: int) -> float:
        dt = epoch - sn.ignite_epoch
        if dt < 0:
            return 0.0
        rise, decay = 1.0, 6.0
        return sn.peak * min(dt / rise, 1.0) * np.exp(-max(dt - rise, 0) / decay)

    def region_image(self, region: int, epoch: int) -> np.ndarray:
        img = self._stars[region].copy()
        for sn in self.supernovae:
            if sn.region == region:
                img[sn.y, sn.x] += self._light_curve(sn, epoch)
        noise = self.rng.normal(0, 1.0, img.shape).astype(np.float32)
        return img + noise

    def _maybe_ignite(self) -> None:
        if self.rng.random() < self.sn_rate * self.layout.n_regions / 8:
            px = self.layout.region_px
            self.supernovae.append(
                Supernova(
                    region=int(self.rng.integers(self.layout.n_regions)),
                    x=int(self.rng.integers(px)),
                    y=int(self.rng.integers(px)),
                    ignite_epoch=self.epoch,
                    peak=float(self.rng.uniform(300, 900)),
                )
            )

    def _region_patch(self, r: int) -> np.ndarray:
        img = self.region_image(r, self.epoch)
        buf = np.zeros(self.layout.region_bytes, np.uint8)
        raw = img.tobytes()
        buf[: len(raw)] = np.frombuffer(raw, np.uint8)
        return buf

    def observe_epoch_stream(self) -> int:
        """Stream one epoch's region patches through the session's bounded
        ``write_async`` window (overlapped write pipelines, backpressure once
        the window fills) and join it; returns the epoch's published version.
        This is the telescope as the paper means it: a producer that never
        stops imaging to wait for the previous frame's metadata round-trip."""
        self.epoch += 1
        self._maybe_ignite()
        for r in range(self.layout.n_regions):
            self.handle.write_async(self._region_patch(r), r * self.layout.region_bytes)
        self.session.flush()
        return self.handle.latest_published()

    def observe_epoch(self, concurrent: bool = True) -> int:
        """Image every region and WRITE the patches; returns the published
        version of this epoch. Telescopes (threads) write concurrently."""
        self.epoch += 1
        self._maybe_ignite()

        def write_region(r: int) -> None:
            img = self.region_image(r, self.epoch)
            buf = np.zeros(self.layout.region_bytes, np.uint8)
            raw = img.tobytes()
            buf[: len(raw)] = np.frombuffer(raw, np.uint8)
            self.handle.write(buf, r * self.layout.region_bytes)

        if concurrent:
            threads = [
                threading.Thread(target=write_region, args=(r,))
                for r in range(self.layout.n_regions)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for r in range(self.layout.n_regions):
                write_region(r)
        return self.handle.latest_published()

    def read_region(self, region: int, version: Optional[int] = None) -> np.ndarray:
        px = self.layout.region_px
        res = self.handle.read(
            region * self.layout.region_bytes, px * px * 4, version=version
        )
        return np.frombuffer(res.data.tobytes(), np.float32).reshape(px, px)


def detect_transients(
    before: np.ndarray, after: np.ndarray, threshold: float = 100.0
) -> List[Tuple[int, int, float]]:
    """Difference imaging: pixels that brightened by more than ``threshold``."""
    diff = after - before
    ys, xs = np.where(diff > threshold)
    return [(int(x), int(y), float(diff[y, x])) for x, y in zip(xs, ys)]
