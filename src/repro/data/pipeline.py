"""Sharded, concurrent-reader training-data pipeline over the blob store.

The tokenized corpus lives in a blob (one giant token string — the paper's
global view). Every DP rank reads its own fine-grain segment per step, fully
in parallel with all other ranks (read/read concurrency) and with a writer
appending new data as new versions (read/write concurrency → online dataset
refresh between epochs).

Straggler mitigation: each fetch races a timeout; on expiry the read is
re-issued against replica providers (redundant fetch).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import ThreadPoolExecutor, TimeoutError as FutTimeout
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.cluster import BlobHandle, Session


def write_token_corpus(
    session: Session, tokens: np.ndarray, page_size: int = 1 << 16
) -> BlobHandle:
    """Store an int32 token array as a blob; returns its handle."""
    raw = np.ascontiguousarray(tokens.astype(np.int32)).view(np.uint8)
    size = -(-raw.size // page_size) * page_size
    size = 1 << (size - 1).bit_length()
    handle = session.create(size, page_size)
    padded = np.zeros(size, np.uint8)
    padded[: raw.size] = raw
    handle.write(padded, 0)
    return handle


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    batch_per_rank: int
    seq_len: int
    n_ranks: int
    rank: int
    prefetch: int = 2
    fetch_timeout_s: float = 5.0
    seed: int = 0


class TokenPipeline:
    """Deterministic sharded reader: step ``s`` of rank ``r`` reads segments
    that no other rank touches; restart at step ``s`` reproduces the batch
    exactly (checkpoint-consistent data order)."""

    def __init__(self, handle: BlobHandle, n_tokens: int,
                 cfg: PipelineConfig, version: Optional[int] = None) -> None:
        self.handle = handle
        self.cfg = cfg
        self.n_tokens = n_tokens
        self.version = (
            version if version is not None else handle.latest_published()
        )
        self._pool = ThreadPoolExecutor(max_workers=4)
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._step = 0

    def _segment_for(self, step: int, row: int) -> int:
        """Deterministic shuffled segment index for (step, rank, row)."""
        cfg = self.cfg
        n_segments = self.n_tokens // (cfg.seq_len + 1)
        global_row = (step * cfg.n_ranks + cfg.rank) * cfg.batch_per_rank + row
        # multiplicative hashing permutation (stable across restarts)
        return int((global_row * 2654435761 + cfg.seed) % n_segments)

    def _fetch_row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        seg = self._segment_for(step, row)
        off = seg * (cfg.seq_len + 1) * 4
        fut = self._pool.submit(
            self.handle.read, off, (cfg.seq_len + 1) * 4, self.version
        )
        try:
            res = fut.result(timeout=cfg.fetch_timeout_s)
        except FutTimeout:
            # straggler mitigation: redundant re-fetch (replicas / other
            # providers); first to complete wins
            fut2 = self._pool.submit(
                self.handle.read, off, (cfg.seq_len + 1) * 4, self.version
            )
            res = fut2.result()
        return np.frombuffer(res.data.tobytes(), np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = [self._fetch_row(step, i) for i in range(cfg.batch_per_rank)]
        arr = np.stack(rows)  # (B, S+1)
        return {"tokens": arr[:, :-1].copy(), "labels": arr[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = self._step
        while True:
            yield self.batch_at(step)
            step += 1

    def set_step(self, step: int) -> None:
        """Restart support: resume the data order at a checkpointed step."""
        self._step = step

    def refresh_version(self) -> int:
        """Pick up the latest published corpus version (online refresh while a
        writer appends — the paper's read/write concurrency)."""
        self.version = self.handle.latest_published()
        return self.version
