"""The paper's application: supernovae detection on the versioned sky blob.

A telescope (writer threads) images the sky every epoch into new blob
versions, while detector clients concurrently difference-image consecutive
versions region-by-region (fine-grain reads) — reads and writes overlap
freely (lock-free R/W concurrency).

The detector is the motivating workload for the client page cache and the
vectored data plane: each epoch it re-reads overlapping sky windows (every
window spills one page into its neighbour, and epoch N's "after" snapshot is
epoch N+1's "before"). All windows of one version are fetched in a single
``readv`` — shared boundary pages are deduplicated, each data provider sees
one aggregated RPC — and the re-read half of every comparison comes straight
from the cache, since published versions are immutable.

    PYTHONPATH=src python examples/supernovae.py
"""

import threading

import numpy as np

from repro.core import BlobStore
from repro.data.sky import SkyLayout, SkySimulator, detect_transients

layout = SkyLayout(n_regions=32, region_px=64)
store = BlobStore(n_data_providers=8, n_metadata_providers=8, max_workers=32)
sim = SkySimulator(store, layout, seed=7, sn_rate=0.2)

print(f"sky blob: {layout.n_regions} regions, {layout.blob_bytes >> 20} MB logical")

IMG_BYTES = layout.region_px * layout.region_px * 4
# overlapping sky windows: each region's window spills one page into the next
# region (difference imaging across region borders), so adjacent windows
# share pages and readv deduplicates them
WINDOWS = [
    (r * layout.region_bytes, IMG_BYTES + layout.page_size)
    for r in range(layout.n_regions)
]


def snapshot_windows(version: int) -> list:
    """Fetch every region window of one published version in ONE readv."""
    outs = store.readv(sim.blob_id, version, WINDOWS)
    return [
        o[:IMG_BYTES].view(np.float32).reshape(layout.region_px, layout.region_px)
        for o in outs
    ]


# epoch 1: first light (no detection possible yet)
v_prev = sim.observe_epoch()
detections = {}
det_lock = threading.Lock()

for epoch in range(2, 8):
    # telescope writes the new epoch WHILE detectors read the previous two
    def detect_epoch(v_a: int, v_b: int) -> None:
        before = snapshot_windows(v_a)  # re-read → served from the page cache
        after = snapshot_windows(v_b)
        for r in range(layout.n_regions):
            hits = detect_transients(before[r], after[r], threshold=150.0)
            if hits:
                with det_lock:
                    detections.setdefault(v_b, []).append((r, hits))

    if v_prev > layout.n_regions:  # have two epochs to compare
        t_detect = threading.Thread(
            target=detect_epoch, args=(v_prev - layout.n_regions, v_prev)
        )
        t_detect.start()
    else:
        t_detect = None

    v_new = sim.observe_epoch()  # concurrent write of the next epoch
    if t_detect:
        t_detect.join()
    print(f"epoch {epoch}: published v{v_new} "
          f"({store.metadata.total_nodes()} metadata nodes, "
          f"{store.storage_bytes() >> 20} MB stored)")
    v_prev = v_new

print("\nground truth supernovae:",
      [(sn.region, sn.x, sn.y, sn.ignite_epoch) for sn in sim.supernovae])
found = sorted({(r, x, y) for hits in detections.values()
                for r, hs in hits for x, y, _ in hs})
print("detected transients:   ", found)
truth = {(sn.region, sn.x, sn.y) for sn in sim.supernovae}
recovered = truth & set(found)
print(f"recovered {len(recovered)}/{len(truth)} supernovae")
hits, misses = store.stats.cache_hits, store.stats.cache_misses
print(f"page cache: {hits} hits / {misses} misses "
      f"({hits / (hits + misses):.0%} hit rate), "
      f"{store.stats.data_rounds} aggregated provider RPC rounds")
store.close()
