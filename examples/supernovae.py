"""The paper's application: supernovae detection on the versioned sky blob.

A telescope (writer threads) images the sky every epoch into new blob
versions, while detector clients concurrently difference-image consecutive
versions region-by-region (fine-grain reads) — reads and writes overlap
freely (lock-free R/W concurrency).

    PYTHONPATH=src python examples/supernovae.py
"""

import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import BlobStore
from repro.data.sky import SkyLayout, SkySimulator, detect_transients

layout = SkyLayout(n_regions=32, region_px=64)
store = BlobStore(n_data_providers=8, n_metadata_providers=8, max_workers=32)
sim = SkySimulator(store, layout, seed=7, sn_rate=0.2)

print(f"sky blob: {layout.n_regions} regions, {layout.blob_bytes >> 20} MB logical")

# epoch 1: first light (no detection possible yet)
v_prev = sim.observe_epoch()
detections = {}
det_lock = threading.Lock()

for epoch in range(2, 8):
    # telescope writes the new epoch WHILE detectors read the previous two
    def detect_epoch(v_a: int, v_b: int) -> None:
        def scan_region(r: int):
            before = sim.read_region(r, v_a)
            after = sim.read_region(r, v_b)
            hits = detect_transients(before, after, threshold=150.0)
            if hits:
                with det_lock:
                    detections.setdefault(v_b, []).append((r, hits))

        with ThreadPoolExecutor(max_workers=8) as pool:
            list(pool.map(scan_region, range(layout.n_regions)))

    t_detect = threading.Thread(target=detect_epoch, args=(v_prev - 0, v_prev))
    if v_prev > layout.n_regions:  # have two epochs to compare
        t_detect = threading.Thread(
            target=detect_epoch, args=(v_prev - layout.n_regions, v_prev)
        )
        t_detect.start()
    else:
        t_detect = None

    v_new = sim.observe_epoch()  # concurrent write of the next epoch
    if t_detect:
        t_detect.join()
    print(f"epoch {epoch}: published v{v_new} "
          f"({store.metadata.total_nodes()} metadata nodes, "
          f"{store.storage_bytes() >> 20} MB stored)")
    v_prev = v_new

print("\nground truth supernovae:",
      [(sn.region, sn.x, sn.y, sn.ignite_epoch) for sn in sim.supernovae])
found = sorted({(r, x, y) for hits in detections.values()
                for r, hs in hits for x, y, _ in hs})
print("detected transients:   ", found)
truth = {(sn.region, sn.x, sn.y) for sn in sim.supernovae}
recovered = truth & set(found)
print(f"recovered {len(recovered)}/{len(truth)} supernovae")
store.close()
