"""The paper's application: supernovae detection on the versioned sky blob.

One :class:`Cluster` models the deployment; the paper's N concurrent clients
are real :class:`Session` objects on it:

* a **writer session** — the telescope — streams each epoch's region patches
  through ``write_async`` (bounded in-flight window, overlapped write
  pipelines) while detectors are still reading earlier frames;
* **N detector sessions** subscribe with ``handle.watch()`` and wake when a
  frame finishes publishing (version ``epoch * n_regions``) instead of
  polling; each detector difference-images its share of the sky between two
  pinned :class:`Snapshot`\\ s (lock-free repeated reads);
* a **publish-driven warmer** (``cluster.warm_on_publish``, one per cluster)
  watches the same publications and pulls each fresh frame's hottest pages
  into the shared tier — fed by the replica balancer's read-heat counters —
  while the detectors are still crunching the previous frame, so their
  FIRST reads of a new frame are warm.

The detectors share the cluster's intra-node cache tier: epoch N's "after"
frame is epoch N+1's "before", so half of every comparison is RAM served —
and one detector's fetch (or the warmer's readahead) warms every other
session on the node (the detector sessions run with no private cache at
all). Reads and writes overlap freely (lock-free R/W concurrency).

    PYTHONPATH=src python examples/supernovae.py
"""

import threading

import numpy as np

from repro.core import Cluster
from repro.data.sky import SkyLayout, SkySimulator, detect_transients

N_DETECTORS = 4
N_EPOCHS = 8

layout = SkyLayout(n_regions=32, region_px=64)
cluster = Cluster(
    n_data_providers=8, n_metadata_providers=8, max_workers=32,
    shared_cache_bytes=256 << 20,
)
writer = cluster.session(max_inflight_writes=8)
sim = SkySimulator(writer, layout, seed=7, sn_rate=0.2)
# the frame warmer: one version per region, so a frame boundary is every
# n_regions-th version — only those are worth warming
warmer = cluster.warm_on_publish(
    sim.blob_id, top_pages=256, frame_versions=layout.n_regions
)

print(f"sky blob: {layout.n_regions} regions, {layout.blob_bytes >> 20} MB logical, "
      f"1 telescope session + {N_DETECTORS} detector sessions + 1 frame warmer")

IMG_BYTES = layout.region_px * layout.region_px * 4
# overlapping sky windows: each region's window spills one page into the next
# region (difference imaging across region borders), so adjacent windows —
# owned by DIFFERENT detector sessions — share pages through the shared tier
WINDOWS = [
    (r * layout.region_bytes, IMG_BYTES + layout.page_size)
    for r in range(layout.n_regions)
]

detections = {}
det_lock = threading.Lock()
detector_sessions = [cluster.session(cache_bytes=0) for _ in range(N_DETECTORS)]
#: per detector, (hits, misses) of the FIRST read of each fresh "after"
#: frame — warm exactly when the publish warmer beat the detector to it
first_reads = [[0, 0] for _ in range(N_DETECTORS)]


def detector(d: int) -> None:
    """Watch-driven detector: wakes on publications, compares each complete
    frame against the previous one for its share of the regions."""
    session = detector_sessions[d]
    handle = session.open(sim.blob_id)
    watch = handle.watch(start_version=0)
    regions = range(d, layout.n_regions, N_DETECTORS)
    for epoch in range(2, N_EPOCHS + 1):
        target = epoch * layout.n_regions  # frame `epoch` fully published
        while True:
            v = watch.next(timeout=60)
            assert v is not None, "writer stalled"
            if v >= target:
                break
        # two pinned snapshots: the frame pair is immune to the writer AND
        # to any GC of older frames while the comparison runs
        with handle.at(target - layout.n_regions) as before, handle.at(target) as after:
            segs = [WINDOWS[r] for r in regions]
            before_w = before.readv(segs)
            h0, m0 = session.stats.cache_hits, session.stats.cache_misses
            after_w = after.readv(segs)  # the fresh frame: warmer territory
            first_reads[d][0] += session.stats.cache_hits - h0
            first_reads[d][1] += session.stats.cache_misses - m0
        for r, b, a in zip(regions, before_w, after_w):
            img_b = b[:IMG_BYTES].view(np.float32).reshape(layout.region_px, -1)
            img_a = a[:IMG_BYTES].view(np.float32).reshape(layout.region_px, -1)
            hits = detect_transients(img_b, img_a, threshold=150.0)
            if hits:
                with det_lock:
                    detections.setdefault(epoch, []).append((r, hits))


threads = [threading.Thread(target=detector, args=(d,)) for d in range(N_DETECTORS)]
for t in threads:
    t.start()

# the telescope streams every epoch through the async write window WHILE the
# detector fleet is comparing earlier frames
for epoch in range(1, N_EPOCHS + 1):
    v = sim.observe_epoch_stream()
    print(f"epoch {epoch}: published v{v} "
          f"({cluster.metadata.total_nodes()} metadata nodes, "
          f"{cluster.storage_bytes() >> 20} MB stored)")

for t in threads:
    t.join()

print("\nground truth supernovae:",
      [(sn.region, sn.x, sn.y, sn.ignite_epoch) for sn in sim.supernovae])
found = sorted({(r, x, y) for hits in detections.values()
                for r, hs in hits for x, y, _ in hs})
print("detected transients:   ", found)
truth = {(sn.region, sn.x, sn.y) for sn in sim.supernovae}
recovered = truth & set(found)
print(f"recovered {len(recovered)}/{len(truth)} supernovae")

hits = sum(s.stats.cache_hits for s in detector_sessions)
misses = sum(s.stats.cache_misses for s in detector_sessions)
f_hits = sum(f[0] for f in first_reads)
f_misses = sum(f[1] for f in first_reads)
print(f"shared cache tier, aggregated over {N_DETECTORS} detector sessions: "
      f"{hits} hits / {misses} misses "
      f"({hits / (hits + misses):.0%} hit rate), "
      f"{cluster.stats.data_rounds} aggregated provider RPC rounds")
print(f"frame warmer: {warmer.pages_warmed} pages warmed across "
      f"{len(warmer.warmed_versions())} frames; fresh-frame first reads "
      f"{f_hits / (f_hits + f_misses):.0%} warm "
      f"({f_hits} hits / {f_misses} misses)")
for d, s in enumerate(detector_sessions):
    print(f"  detector {d}: hit rate {s.cache_hit_rate:.0%}, "
          f"first-read hit rate "
          f"{first_reads[d][0] / max(sum(first_reads[d]), 1):.0%}")
cluster.close()
