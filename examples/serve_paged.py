"""Serve a small model over the BLOB-BACKED paged KV cache: two independent
engines ("users") on one cluster share prompt-prefix pages through the
cluster-wide content-addressed prefix directory — engine B never recomputes
or re-stores the system prompt engine A published.

    PYTHONPATH=src python examples/serve_paged.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Cluster
from repro.models.lm import build_model
from repro.serving.blob_kv import BlobKVClient, BlobKVStore
from repro.serving.engine import Request, ServingEngine

cfg = get_config("llama3_2-1b").smoke()
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

# one cluster, one KV pool blob; each engine is an independent session
cluster = Cluster(n_data_providers=2, n_metadata_providers=2)
n_layers = cfg.n_layers if cfg.family not in ("encdec", "audio") else cfg.n_dec_layers
store = BlobKVStore.for_kv(
    cluster, n_pages=256, page_tokens=cfg.kv_page_tokens,
    n_layers=n_layers, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
    dtype=np.dtype("uint16"),  # bf16 pages travel as 2-byte payloads
)
engine_a = ServingEngine(cfg, params, max_slots=4, kv_client=BlobKVClient(store))
engine_b = ServingEngine(cfg, params, max_slots=4, kv_client=BlobKVClient(store))

rng = np.random.default_rng(0)
system_prompt = rng.integers(0, cfg.vocab_size, 24).tolist()  # shared by all

t0 = time.time()
for i in range(5):
    user = rng.integers(0, cfg.vocab_size, 8).tolist()
    engine_a.submit(Request(i, system_prompt + user, max_new_tokens=12))
done_a = engine_a.run_until_drained()

# engine B (a different user session) admits the same system prompt: its
# prefix pages resolve through the cluster directory to A's published pages
for i in range(5):
    user = rng.integers(0, cfg.vocab_size, 8).tolist()
    engine_b.submit(Request(i, system_prompt + user, max_new_tokens=12))
done_b = engine_b.run_until_drained()
dt = time.time() - t0

done = {**done_a, **{k + 100: v for k, v in done_b.items()}}
total = sum(len(c.tokens) for c in done.values())
hits = sum(c.prefill_skipped_tokens for c in done.values())
cross = sum(c.prefill_skipped_tokens for c in done_b.values())
print(f"{len(done)} completions / {total} tokens in {dt:.1f}s ({total / dt:.1f} tok/s)")
print(f"prefix directory: {hits} prompt tokens served from shared published pages")
print(f"  of which {cross} crossed engines (B reading A's published prefix)")
print(f"store stats: {store.stats}")
print(f"directory: {len(cluster.page_directory)} entries, "
      f"hit rate {cluster.page_directory.hit_rate:.2f}")
assert len(done) == 10
assert cross > 0, "engine B should share engine A's published prefix pages"
print("serve_paged OK")
