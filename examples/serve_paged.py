"""Serve a small model with batched requests over the paged COW KV cache:
continuous batching, prefix-cache sharing, backpressure.

    PYTHONPATH=src python examples/serve_paged.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serving.engine import Request, ServingEngine

cfg = get_config("llama3_2-1b").smoke()
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
engine = ServingEngine(cfg, params, max_slots=4, n_pages=256)

rng = np.random.default_rng(0)
system_prompt = rng.integers(0, cfg.vocab_size, 24).tolist()  # shared by all

t0 = time.time()
for i in range(10):
    user = rng.integers(0, cfg.vocab_size, 8).tolist()
    engine.submit(Request(i, system_prompt + user, max_new_tokens=12))

done = engine.run_until_drained()
dt = time.time() - t0
total = sum(len(c.tokens) for c in done.values())
hits = sum(c.prefill_skipped_tokens for c in done.values())
print(f"{len(done)} completions / {total} tokens in {dt:.1f}s ({total / dt:.1f} tok/s)")
print(f"prefix-cache: {hits} prompt tokens served from shared COW pages")
print(f"pool stats: {engine.alloc.stats}")
assert len(done) == 10
print("serve_paged OK")
