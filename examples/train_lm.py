"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on CPU, with the full production substrate engaged — blob-store
data pipeline, incremental COW checkpoints, restart-after-failure.

    PYTHONPATH=src python examples/train_lm.py              # full (~100M, 200 steps)
    PYTHONPATH=src python examples/train_lm.py --tiny       # CI-sized
"""

import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.tiny:
        steps = args.steps or 30
        out = train("llama3_2-1b", smoke=True, steps=steps, batch=8, seq=64,
                    checkpoint_every=10, lr=1e-2)
    else:
        # ~100M params: a reduced llama (d=512, 8 layers, vocab 32000)
        import repro.configs.llama3_2_1b as base
        from repro.models.config import ModelConfig

        cfg100m = dataclasses.replace(
            base.CONFIG, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000, attn_chunk=128,
            remat="none", grad_accum=1,
        )
        print(f"~{cfg100m.param_count() / 1e6:.0f}M parameters")

        # monkey-patch the registry entry for the launcher
        import repro.configs as C

        orig = C.get_config
        C.get_config = lambda a: cfg100m if a == "llama3_2-1b" else orig(a)
        try:
            steps = args.steps or 200
            out = train("llama3_2-1b", steps=steps, batch=8, seq=256,
                        checkpoint_every=50, lr=3e-3)
        finally:
            C.get_config = orig

    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    ck = out["checkpointer"]
    print(f"checkpoints retained: {[c.step for c in ck.checkpoints]}, "
          f"store holds {out['session'].cluster.storage_bytes() >> 20} MB "
          f"(incremental dirty pages last save: {ck.checkpoints[-1].dirty_pages})")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not decrease"
    print("train_lm OK")


if __name__ == "__main__":
    main()
