"""Quickstart: the layered Cluster / Session / BlobHandle API in 60 lines.

One Cluster (shared plane), many Sessions (concurrent clients), BlobHandles
for fine-grain ops: ALLOC a terabyte-scale blob, WRITE patches from
concurrent sessions, pin immutable Snapshots, react to publications with a
version watch, and survive a provider failure.

    PYTHONPATH=src python examples/quickstart.py
"""

import threading

import numpy as np

from repro.core import Cluster

PAGE = 64 << 10  # 64 KB pages (paper §V)

cluster = Cluster(n_data_providers=8, n_metadata_providers=8, page_replication=2)
blob = cluster.alloc(1 << 40, PAGE)  # 1 TB logical, allocate-on-write
print(f"allocated blob {blob}: 1 TB / {PAGE >> 10} KB pages")

# -- version 0 is the all-zero string ---------------------------------------------
main = cluster.session().open(blob)
assert not main.read(0, PAGE, version=0).data.any()

# -- concurrent writer SESSIONS on disjoint segments (lock-free W/W) --------------
def writer(i: int) -> None:
    handle = cluster.session().open(blob)  # one session per client
    seg = np.full(4 * PAGE, i + 1, dtype=np.uint8)
    v = handle.write(seg, i * 4 * PAGE)
    print(f"  writer session {i} published version {v}")

threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
[t.start() for t in threads]
[t.join() for t in threads]
print(f"latest published version: {main.latest_published()}")

# -- snapshot isolation: a pinned version stays readable (R/W concurrency) --------
with main.snapshot() as snap:  # pins the version against writers AND gc
    main.write(np.full(4 * PAGE, 99, np.uint8), 0)  # overwrite writer 0's data
    print(f"snapshot v{snap.version} still reads {snap.read(0, PAGE)[0]}; "
          f"latest reads {main.read(0, PAGE).data[0]}")

# -- watch: react to publications instead of polling ------------------------------
watch = main.watch()
threading.Thread(target=lambda: main.write(np.ones(PAGE, np.uint8), 123 * PAGE)).start()
v = watch.next(timeout=10)
print(f"watch woke for version {v}")

# -- COW metadata sharing ----------------------------------------------------------
nodes_before = cluster.metadata.total_nodes()
main.write(np.ones(PAGE, np.uint8), 200 * PAGE)  # 1-page patch
print(f"1-page patch on a 1 TB blob created only "
      f"{cluster.metadata.total_nodes() - nodes_before} metadata nodes (tree height), "
      f"total bytes stored: {cluster.storage_bytes() >> 10} KB")

# -- fault tolerance: page replication survives provider loss ----------------------
cluster.provider_manager.fail_provider(0)
ok = main.read(0, 4 * PAGE)
print(f"provider 0 down: read still fine via replicas ({ok.data[0]})")
cluster.close()
print("quickstart OK")
