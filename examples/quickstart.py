"""Quickstart: the paper's blob-store API in 60 lines.

ALLOC a terabyte-scale blob, WRITE fine-grain patches from concurrent
clients, READ any published version (snapshots), watch COW share pages.

    PYTHONPATH=src python examples/quickstart.py
"""

import threading

import numpy as np

from repro.core import BlobStore

PAGE = 64 << 10  # 64 KB pages (paper §V)

store = BlobStore(n_data_providers=8, n_metadata_providers=8, page_replication=2)
blob = store.alloc(1 << 40, PAGE)  # 1 TB logical, allocate-on-write
print(f"allocated blob {blob}: 1 TB / {PAGE >> 10} KB pages")

# -- version 0 is the all-zero string ---------------------------------------------
z = store.read(blob, 0, 0, PAGE)
assert not z.data.any()

# -- concurrent writers on disjoint segments (lock-free W/W) ----------------------
def writer(i: int) -> None:
    seg = np.full(4 * PAGE, i + 1, dtype=np.uint8)
    v = store.write(blob, seg, i * 4 * PAGE)
    print(f"  writer {i} published version {v}")

threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
[t.start() for t in threads]
[t.join() for t in threads]

latest = store.version_manager.latest_published(blob)
print(f"latest published version: {latest}")

# -- snapshot isolation: old versions stay readable (R/W concurrency) -------------
v_snap = latest
store.write(blob, np.full(4 * PAGE, 99, np.uint8), 0)  # overwrite writer 0's data
old = store.read(blob, v_snap, 0, PAGE).data[0]
new = store.read(blob, None, 0, PAGE).data[0]
print(f"snapshot v{v_snap} still reads {old}; latest reads {new}")

# -- COW metadata sharing ----------------------------------------------------------
nodes_before = store.metadata.total_nodes()
store.write(blob, np.ones(PAGE, np.uint8), 123 * PAGE)  # 1-page patch
nodes_after = store.metadata.total_nodes()
print(f"1-page patch on a 1 TB blob created only {nodes_after - nodes_before} "
      f"metadata nodes (tree height), total bytes stored: {store.storage_bytes() >> 10} KB")

# -- fault tolerance: page replication survives provider loss ----------------------
store.provider_manager.fail_provider(0)
ok = store.read(blob, None, 0, 4 * PAGE)
print(f"provider 0 down: read still fine via replicas ({ok.data[0]})")
store.close()
print("quickstart OK")
