"""Tests for the concurrency-correctness toolkit (repro.analysis).

Three parts, mirroring the toolkit:

* the static lint — seeded-violation fixtures in ``tests/lint_fixtures/``
  (parsed, never imported) must each be flagged at the marked line with the
  marked rule, and the real tree must lint clean;
* the runtime lock-order watchdog — seeded ABBA / reversed-order / join-
  under-lock patterns on private ``LockWatch`` instances must be reported,
  and the disabled path must return a plain ``threading.Lock``;
* the deterministic interleaving explorer — every registered scenario must
  pass under EVERY schedule, and a scenario seeded with an order bug must
  be caught at exactly the offending interleaving.
"""

from __future__ import annotations

import os
import re
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from types import SimpleNamespace

import pytest

from repro.analysis import lock_order, lockwatch, schedules
from repro.analysis.lint import lint_paths
from repro.analysis.lockwatch import (
    LockWatch,
    WatchedLock,
    install_blocking_hooks,
    make_lock,
    remove_blocking_hooks,
)

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "lint_fixtures")
SRC = os.path.normpath(os.path.join(HERE, "..", "src", "repro"))

_EXPECT_RE = re.compile(r"#\s*EXPECT\s+([a-z-]+)")


# -- static lint --------------------------------------------------------------

def _expected_markers():
    """(basename, line, rule) for every ``# EXPECT rule`` marker."""
    expected = set()
    for name in sorted(os.listdir(FIXTURES)):
        if not name.endswith(".py"):
            continue
        with open(os.path.join(FIXTURES, name), encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, start=1):
                m = _EXPECT_RE.search(line)
                if m:
                    expected.add((name, lineno, m.group(1)))
    return expected


def test_fixture_markers_flagged_exactly():
    """Every seeded violation is flagged at its file:line with its rule —
    and nothing else in the fixtures is flagged (pragma suppression and the
    legal-pattern controls stay quiet)."""
    expected = _expected_markers()
    assert len(expected) >= 6, "fixture set lost its seeded violations"
    got = {
        (os.path.basename(v.path), v.line, v.rule)
        for v in lint_paths([FIXTURES])
    }
    assert got == expected


def test_fixture_rules_cover_required_set():
    rules = {rule for _, _, rule in _expected_markers()}
    assert {
        "blocking-under-lock", "lock-order", "undeclared-lock",
        "facade-import", "fulfill-without-plan", "direct-store-mutation",
    } <= rules


def test_real_tree_lints_clean():
    violations = lint_paths([SRC])
    assert not violations, "\n".join(str(v) for v in violations)


def test_lint_finds_raw_lock_in_core_scope(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    mod = core / "mod.py"
    mod.write_text("import threading\nL = threading.Lock()\n")
    assert [v.rule for v in lint_paths([str(tmp_path)])] == ["raw-lock"]


def test_lock_order_registry_is_consistent():
    levels = {spec.name: spec.level for spec in lock_order.LOCKS}
    assert len(levels) == len(lock_order.LOCKS), "duplicate lock names"
    # the helper agrees with the table in both directions
    assert lock_order.order_violation("Cluster._gc_guard", "PageCache._lock") is None
    assert lock_order.order_violation("PageCache._lock", "Cluster._gc_guard")
    assert lock_order.order_violation("PageCache._lock", "TrafficStats._lock")
    assert lock_order.order_violation("PageCache._lock", "PageCache._lock")


# -- runtime watchdog ---------------------------------------------------------

def test_make_lock_disabled_is_plain_lock(monkeypatch):
    monkeypatch.delenv(lockwatch.ENV_VAR, raising=False)
    lock = make_lock("PageCache._lock")
    assert type(lock) is type(threading.Lock())  # zero-overhead by identity


def test_watchdog_reports_abba_cycle():
    w = LockWatch()
    a = WatchedLock("TestA._lock", w)  # undeclared on purpose: no order rule,
    b = WatchedLock("TestB._lock", w)  # the CYCLE check alone must fire
    with a:
        with b:
            pass
    with b:
        with a:  # second ordering closes the ABBA cycle
            pass
    assert any(v.rule == "lock-cycle" for v in w.violations), w.violations
    msg = next(v for v in w.violations if v.rule == "lock-cycle").message
    assert "TestA._lock" in msg and "TestB._lock" in msg


def test_watchdog_reports_declared_order_violation():
    w = LockWatch()
    leaf = WatchedLock("PageCache._lock", w)  # level 5
    guard = WatchedLock("Cluster._gc_guard", w)  # level 1
    with guard:
        with leaf:
            pass  # correct direction: silent
    assert not w.violations
    with leaf:
        with guard:
            pass  # reversed: flagged immediately, no deadlock needed
    assert any(v.rule == "lock-order" for v in w.violations), w.violations


def test_watchdog_reports_same_name_reacquire():
    w = LockWatch()
    first = WatchedLock("PageCache._lock", w)
    second = WatchedLock("PageCache._lock", w)  # distinct instance, same class
    with first:
        with second:
            pass
    assert any(
        v.rule == "lock-cycle" and "re-acquiring" in v.message
        for v in w.violations
    ), w.violations


def test_watchdog_trylock_excluded_from_cycles():
    w = LockWatch()
    a = WatchedLock("TestA._lock", w)
    b = WatchedLock("TestB._lock", w)
    with a:
        with b:
            pass
    with b:
        assert a.acquire(blocking=False)  # trylock: cannot deadlock
        a.release()
    assert not w.violations, w.violations
    assert "TestA._lock" in w.try_edges.get("TestB._lock", set())


def test_join_under_lock_reported_and_done_future_exempt():
    w = LockWatch()
    had_hooks = lockwatch._HOOKS is not None
    if had_hooks:
        remove_blocking_hooks()
    install_blocking_hooks(target=w)
    try:
        lock = WatchedLock("PageCache._lock", w)  # strict leaf lock
        with ThreadPoolExecutor(max_workers=1) as pool:
            done = pool.submit(lambda: 1)
            assert done.result() == 1  # completes; now provably non-blocking
            with lock:
                assert done.result() == 1  # done future: exempt
            assert not w.violations, w.violations
            with lock:
                pool.submit(time.sleep, 0.05).result()  # real wait under lock
        assert any(v.rule == "join-under-lock" for v in w.violations)
        assert "PageCache._lock" in w.violations[-1].message
    finally:
        remove_blocking_hooks()
        if had_hooks and lockwatch.enabled():
            install_blocking_hooks()


def test_watched_condition_wait_keeps_stack_truthful(monkeypatch):
    monkeypatch.setenv(lockwatch.ENV_VAR, "1")
    cv = lockwatch.make_condition("WatchWarmer._cv")
    with cv:
        cv.wait(timeout=0.01)  # releases + re-acquires the aliased lock
        assert lockwatch.watch().held() == ("WatchWarmer._cv",)
    assert lockwatch.watch().held() == ()
    lockwatch.watch().assert_clean(reset=True)


def test_make_lock_undeclared_name_recorded(monkeypatch):
    monkeypatch.setenv(lockwatch.ENV_VAR, "1")
    make_lock("Nowhere._lock")
    with pytest.raises(AssertionError, match="undeclared-lock"):
        lockwatch.watch().assert_clean(reset=True)


# -- core fixes that ride along ----------------------------------------------

def test_cluster_close_is_idempotent_and_joins_warmers():
    from repro.core.cluster import Cluster

    cluster = Cluster(n_data_providers=2, n_metadata_providers=2, max_workers=2)
    blob = cluster.alloc(4 * 4096, 4096)
    warmer = cluster.warm_on_publish(blob)
    cluster.close()
    assert not warmer._thread.is_alive(), "close() must join warmer threads"
    cluster.close()  # second close: no-op, no error


def test_provider_fail_recover_serializes_on_provider_lock():
    from repro.core.dht import ProviderFailed
    from repro.core.provider import DataProvider, ProviderManager
    import numpy as np

    manager = ProviderManager(replication=1)
    provider = DataProvider(0)
    manager.register(provider)
    manager.fail_provider(0)
    with pytest.raises(ProviderFailed):
        provider.put_pages([(0, np.zeros(8, dtype=np.uint8))])
    manager.recover_provider(0)
    provider.put_pages([(0, np.zeros(8, dtype=np.uint8))])
    assert provider.n_pages == 1


# -- interleaving explorer ----------------------------------------------------

def test_interleavings_enumerates_all_merges():
    orders = list(schedules.interleavings([2, 2]))
    assert len(orders) == 6 == schedules.n_interleavings([2, 2])
    assert len(set(orders)) == 6
    for order in orders:
        assert [i for i in order if i == 0] == [0, 0]  # per-actor order kept
        assert [i for i in order if i == 1] == [1, 1]


def test_explorer_refuses_unbounded_scenarios():
    scenario = schedules.SCENARIOS["publish_vs_shared_fill"]
    with pytest.raises(ValueError, match="interleavings exceed"):
        schedules.explore(scenario, max_schedules=2)


def test_explorer_catches_seeded_order_bug():
    """A scenario with a real ordering bug: the explorer must report exactly
    the schedule where the reader outruns the writer."""

    def build():
        fake_cluster = SimpleNamespace(close=lambda: None)
        return SimpleNamespace(cluster=fake_cluster, errors=[], published=False)

    def actors(ctx):
        def publish():
            ctx.published = True

        def read():
            if not ctx.published:
                ctx.errors.append("read before publish")

        return [("writer", [publish]), ("reader", [read])]

    report = schedules.explore(
        schedules.Scenario("seeded_order_bug", build, actors))
    assert report.n_schedules == 2
    assert len(report.failures) == 1
    failure = report.failures[0]
    assert failure.schedule[0] == "reader.0"
    assert "read before publish" in failure.errors[0]


def test_required_scenarios_registered():
    assert {"gc_vs_pin", "publish_vs_shared_fill"} <= set(schedules.SCENARIOS)
    assert len(schedules.SCENARIOS) >= 4


@pytest.mark.parametrize("name", sorted(schedules.SCENARIOS))
def test_scenario_passes_every_schedule(name):
    report = schedules.explore(schedules.SCENARIOS[name])
    assert report.n_schedules >= 2
    assert report.ok, "\n".join(str(f) for f in report.failures)
