"""Federated multi-node clusters: the GC epoch/lease protocol, fencing,
node death, and the version-abandon wakeup satellites.

Every lease test injects a fake clock into the coordinator AND the retry
policy's sleep, so lease expiry, renew-under-GC races and lease wait-outs
are driven deterministically — no wall-clock sleeps, no flakes.
"""

import threading

import numpy as np
import pytest

from repro.core import (
    Federation,
    GcEpochCoordinator,
    HealthConfig,
    ProviderFailed,
    RetryPolicy,
    VersionAbandoned,
    VersionManager,
    VersionWatch,
)

PAGE = 256
PAGES = 8


class FakeClock:
    def __init__(self, start=1000.0):
        self.now = start

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


def make_fed(clock, n_nodes=2, lease_seconds=10.0, dead_after=100):
    return Federation(
        n_nodes=n_nodes,
        n_data_providers=2,
        n_metadata_providers=2,
        max_workers=2,
        lease_seconds=lease_seconds,
        clock=clock,
        retry_policy=RetryPolicy(max_attempts=1, sleep=clock.advance),
        health=HealthConfig(dead_after=dead_after, window_seconds=1e9,
                            clock=clock),
    )


def fill(value, n_bytes=PAGE * PAGES):
    return np.full(n_bytes, value, np.uint8)


# ------------------------------ shared substrate -------------------------------


def test_cross_node_read_your_publishes():
    clock = FakeClock()
    fed = make_fed(clock)
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session()
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    assert h0.wait_for_version(v1, timeout=5.0)
    h1 = s1.open(h0.blob_id)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    # both nodes share ONE frontier: a publish on node 1 is node 0's too
    v2 = h1.write(fill(2), 0)
    assert h0.wait_for_version(v2, timeout=5.0)
    np.testing.assert_array_equal(h0.read(0, PAGE * PAGES).data, fill(2))
    fed.close()


# ------------------------------ lease fencing ----------------------------------


def test_lease_expiry_fences_before_next_cache_serve():
    """The fencing invariant, deterministically: a node whose lease lapses
    while partitioned purges its cache tiers BEFORE the next cache serve and
    reads through to the providers — it can never serve a page federated GC
    may have reclaimed behind its back."""
    clock = FakeClock()
    fed = make_fed(clock, lease_seconds=10.0)
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session(cache_bytes=0)  # fills land in node 1's shared tier
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    h1 = s1.open(h0.blob_id)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    assert fed.nodes[1].shared_cache.cached_versions(h0.blob_id) == [v1]

    fed.apply_node_fault(1, "partition")
    clock.advance(11.0)  # the lease expires mid-life, no renewal possible
    assert not fed.coordinator.lease_valid(1)
    # next read: fence FIRST (purge), then read through — still correct
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    assert fed.node_fenced(1)
    assert fed.nodes[1].shared_cache.cached_versions(h0.blob_id) == []
    assert fed.nodes[1].stats.lease_fences == 1
    assert fed.stats.lease_fences == 1
    # further fenced reads do not re-purge (one fence per transition) and
    # never fill the tiers
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    assert fed.nodes[1].stats.lease_fences == 1
    assert fed.nodes[1].shared_cache.cached_versions(h0.blob_id) == []

    fed.apply_node_fault(1, "recover")
    assert not fed.node_fenced(1)
    assert fed.coordinator.lease_valid(1)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    assert fed.nodes[1].shared_cache.cached_versions(h0.blob_id) == [v1]
    fed.close()


def test_renew_under_gc_fences_and_rejoins_as_ack():
    """The renew-under-GC race: a renewal that discovers the epoch advanced
    underneath the lease must fence (purge) and rejoin at the current epoch
    — which IS the ack the GC pass waits for."""
    clock = FakeClock()
    fed = make_fed(clock, lease_seconds=10.0)
    s1 = fed.nodes[1].session(cache_bytes=0)
    h1 = s1.create(PAGE * PAGES, PAGE)
    h1.write(fill(1), 0)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))

    # near expiry with a matching epoch: the guard renews inline, no fence
    clock.advance(6.0)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    assert fed.coordinator.seconds_until_expiry(1) == 10.0
    assert fed.nodes[1].stats.lease_fences == 0

    # an epoch advances under the lease (a GC pass elsewhere): the next
    # near-expiry renewal fails, fences, and rejoins at the new epoch
    epoch = fed.coordinator.advance_epoch()
    clock.advance(6.0)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(1))
    assert fed.nodes[1].stats.lease_fences == 1
    assert fed.coordinator.joined_epoch(1) == epoch
    assert not fed.node_fenced(1)  # rejoined: serving again from empty tiers
    fed.close()


def test_gc_waits_out_partitioned_nodes_lease_and_records_stall():
    """A partitioned node cannot ack: the GC pass stalls until the node's
    lease expires (counted in epoch_stalls), then reclaims safely — the
    expired node fences before it could ever serve a collected page."""
    clock = FakeClock()
    fed = make_fed(clock, lease_seconds=10.0)
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session(cache_bytes=0)
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    h1 = s1.open(h0.blob_id)
    h1.read(0, PAGE * PAGES)  # node 1 caches v1's pages
    v2 = h0.write(fill(2), 0)

    fed.apply_node_fault(1, "partition")
    epoch_before = fed.coordinator.epoch()
    fed.gc(h0.blob_id, keep_versions=[v2])  # wait-out runs on the fake clock
    assert fed.coordinator.epoch() == epoch_before + 1
    assert fed.stats.epoch_stalls == 1
    assert not fed.coordinator.lease_valid(1)  # reclaimed only past expiry
    # v1 is gone from storage; the partitioned node's NEXT serve fences, so
    # its stale v1 pages can never be observed
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(2))
    assert fed.node_fenced(1)
    assert fed.nodes[1].shared_cache.cached_versions(h0.blob_id) == []
    with pytest.raises(KeyError):
        h1.read(0, PAGE * PAGES, version=v1)
    fed.close()


def test_federated_gc_honors_other_nodes_snapshot_pins():
    clock = FakeClock()
    fed = make_fed(clock)
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session()
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    h1 = s1.open(h0.blob_id)
    snap = h1.at(v1)  # node 1 pins v1 at the coordinator
    v2 = h0.write(fill(2), 0)
    fed.nodes[0].gc(h0.blob_id, keep_versions=[v2])
    # the other node's pin vetoed v1's reclaim
    np.testing.assert_array_equal(snap.read(0, PAGE * PAGES), fill(1))
    snap.release()
    assert fed.coordinator.pinned_versions(h0.blob_id) == set()
    fed.gc(h0.blob_id, keep_versions=[v2])
    with pytest.raises(KeyError):
        h1.read(0, PAGE * PAGES, version=v1)
    fed.close()


def test_partitioned_node_pin_refused_safely():
    clock = FakeClock()
    fed = make_fed(clock)
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session()
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    h1 = s1.open(h0.blob_id)
    fed.apply_node_fault(1, "partition")
    # a pin the coordinator cannot see would be silently ignored by GC —
    # refusing it is the only safe answer
    with pytest.raises(ProviderFailed):
        h1.at(v1)
    fed.apply_node_fault(1, "recover")
    snap = h1.at(v1)
    snap.release()
    fed.close()


def test_unpin_lost_while_unreachable_resyncs_on_rejoin():
    """A snapshot released while its node is down cannot deliver its unpin
    to the coordinator (best-effort, swallowed). Without the rejoin-time pin
    resync the coordinator would veto that version's reclaim forever."""
    clock = FakeClock()
    fed = make_fed(clock)
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session()
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    h1 = s1.open(h0.blob_id)
    snap = h1.at(v1)  # node 1 pins v1 at the coordinator
    fed.apply_node_fault(1, "kill")
    snap.release()  # the unpin RPC is lost with the node
    assert fed.coordinator.pinned_versions(h0.blob_id) == {v1}
    v2 = h0.write(fill(2), 0)
    fed.gc(h0.blob_id, keep_versions=[v2])
    # the leaked pin still vetoed this pass (conservative direction) ...
    np.testing.assert_array_equal(
        h0.read(0, PAGE * PAGES, version=v1).data, fill(1)
    )
    # ... but rejoin resyncs the coordinator to the node's local pin table
    fed.apply_node_fault(1, "recover")
    assert fed.coordinator.pinned_versions(h0.blob_id) == set()
    fed.gc(h0.blob_id, keep_versions=[v2])
    with pytest.raises(KeyError):
        h0.read(0, PAGE * PAGES, version=v1)
    fed.close()


# ------------------------------ node death -------------------------------------


def test_node_death_reclaims_lease_pins_and_recovers_writers():
    """A node declared dead mid-pass loses its lease and pins, and its
    sessions' assigned-but-unreported versions are abandoned so in-order
    publication never wedges behind the dead writers."""
    clock = FakeClock()
    fed = make_fed(clock, dead_after=1)
    vm = fed.version_manager
    s0 = fed.nodes[0].session()
    s1 = fed.nodes[1].session()
    h0 = s0.create(PAGE * PAGES, PAGE)
    v1 = h0.write(fill(1), 0)
    h1 = s1.open(h0.blob_id)
    snap = h1.at(v1)  # node 1 holds a pin the death must reclaim

    # node 1 has a write mid-flight: version assigned, success never reported
    (doomed, _links), = vm.assign_versions(h0.blob_id, [(0, PAGES)])
    with s1._async_lock:
        s1._inflight_versions.setdefault(h0.blob_id, set()).add(doomed)

    fed.apply_node_fault(1, "kill")
    fed.gc(h0.blob_id, keep_versions=[v1])  # one failed ack = death verdict
    assert fed.coordinator.node_dead(1)
    assert fed.coordinator.pinned_versions(h0.blob_id) == set()
    assert not fed.coordinator.lease_valid(1)
    # the dead writer's version was withdrawn: the next writer reuses the
    # slot and the frontier advances straight through it
    v_next = h0.write(fill(3), 0)
    assert v_next == doomed
    assert vm.latest_published(h0.blob_id) == v_next

    fed.apply_node_fault(1, "recover")
    assert not fed.coordinator.node_dead(1)
    assert fed.coordinator.lease_valid(1)
    np.testing.assert_array_equal(h1.read(0, PAGE * PAGES).data, fill(3))
    snap.release()  # unpin after death is best-effort, must not raise
    fed.close()


def test_report_success_after_writer_recovery_raises_not_silent_loss():
    """A live-but-partitioned writer whose in-flight version a death verdict
    abandoned must see its write FAIL — silently acking a write that will
    never publish is data loss."""
    vm = VersionManager()
    blob = vm.alloc(PAGES, PAGE)
    v, _ = vm.assign_version(blob, 0, PAGES)
    vm.abandon(blob, [v])  # writer recovery runs while the writer is mid-put
    with pytest.raises(VersionAbandoned):
        vm.report_success(blob, v)


# ------------------------- version-abandon wakeups -----------------------------


def test_abandon_wakes_waiters_fail_fast():
    """Satellite bugfix: a waiter on an awaited version used to block its
    FULL timeout when the version was abandoned after the wait began — the
    abandon must wake it immediately with the aborted-version error."""
    vm = VersionManager()
    blob = vm.alloc(PAGES, PAGE)
    v1, _ = vm.assign_version(blob, 0, PAGES)
    v2, _ = vm.assign_version(blob, 0, PAGES)
    results = []

    def waiter():
        try:
            vm.wait_published(blob, v1, timeout=30.0)
            results.append("published")
        except VersionAbandoned:
            results.append("abandoned")

    t = threading.Thread(target=waiter)
    t.start()
    threading.Event().wait(0.05)  # the waiter is parked on the condition
    vm.abandon(blob, [v1])  # v2 assigned after it -> v1 is an aborted hole
    t.join(5.0)  # must wake NOW, not after the 30s timeout
    assert not t.is_alive()
    assert results == ["abandoned"]

    # the erase case wakes waiters identically (withdrawn, not a hole)
    vm.abandon(blob, [v2])
    with pytest.raises(VersionAbandoned):
        vm.wait_published(blob, v2, timeout=30.0)


def test_version_watch_skips_holes_and_waits_for_reissued_slots():
    vm = VersionManager()
    blob = vm.alloc(PAGES, PAGE)
    watch = VersionWatch(vm, blob, start_version=0)
    v1, _ = vm.assign_version(blob, 0, PAGES)
    v2, _ = vm.assign_version(blob, 0, PAGES)
    vm.abandon(blob, [v1])  # hole: v2 was assigned after it
    vm.report_success(blob, v2)
    # the hole is stepped over without delivery; v2 arrives in order
    assert watch.next(timeout=5.0) == v2

    v3, _ = vm.assign_version(blob, 0, PAGES)
    vm.abandon(blob, [v3])  # erased: the slot number will be reissued
    got = []
    t = threading.Thread(target=lambda: got.append(watch.next(timeout=30.0)))
    t.start()
    threading.Event().wait(0.05)
    # the watch must NOT have consumed the erased slot: when the number is
    # reissued and published, it is delivered
    v3_again, _ = vm.assign_version(blob, 0, PAGES)
    assert v3_again == v3
    vm.report_success(blob, v3_again)
    t.join(5.0)
    assert not t.is_alive()
    assert got == [v3]


# ------------------------------ coordinator unit -------------------------------


def test_coordinator_lease_and_epoch_protocol():
    clock = FakeClock()
    coord = GcEpochCoordinator(lease_seconds=10.0, clock=clock)
    assert coord.join(0) == 1
    assert coord.lease_valid(0)
    clock.advance(6.0)
    assert coord.seconds_until_expiry(0) == 4.0
    assert coord.renew(0)  # epoch matches: extended
    assert coord.seconds_until_expiry(0) == 10.0
    epoch = coord.advance_epoch()
    assert not coord.renew(0)  # epoch mismatch: must fence + rejoin
    assert coord.lease_valid(0)  # but the old lease still blocks reclaim
    assert coord.join(0) == epoch
    clock.advance(11.0)
    assert not coord.lease_valid(0)
    assert not coord.renew(0)  # expired leases cannot be renewed


def test_coordinator_pins_block_during_sweep():
    clock = FakeClock()
    coord = GcEpochCoordinator(lease_seconds=10.0, clock=clock)
    coord.join(0)
    coord.pin(0, blob_id=7, version=3)
    assert coord.begin_sweep(7) == {3}
    landed = threading.Event()

    def late_pinner():
        coord.pin(0, blob_id=7, version=4)  # must wait out the sweep
        landed.set()

    t = threading.Thread(target=late_pinner)
    t.start()
    assert not landed.wait(0.1)  # parked while sweeping
    coord.end_sweep()
    assert landed.wait(5.0)
    t.join(5.0)
    assert coord.pinned_versions(7) == {3, 4}
    coord.unpin(0, 7, 3)
    coord.unpin(0, 7, 4)
    assert coord.pinned_versions(7) == set()


def test_coordinator_death_is_sticky_until_revive():
    clock = FakeClock()
    coord = GcEpochCoordinator(
        lease_seconds=10.0, clock=clock,
        health=HealthConfig(dead_after=2, window_seconds=1e9, clock=clock),
    )
    coord.join(0)
    assert not coord.note_failure(0)
    assert coord.health_state(0) == "suspect"
    assert coord.note_failure(0)  # the death verdict fires exactly once
    assert not coord.note_failure(0)
    assert coord.node_dead(0)
    with pytest.raises(ProviderFailed):
        coord.join(0)  # dead nodes cannot sneak back in via join
    coord.revive(0)
    assert not coord.node_dead(0)
    assert coord.join(0) >= 1
