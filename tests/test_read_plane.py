"""Streaming overlapped read plane + adaptive prefetch tests.

Covers the tentpole property (``get_pages`` issued before the final metadata
traversal level completes — and NOT issued early on the phased ``sync_read``
baseline), stream/phased result equivalence, the ``np.empty``/concatenate
assembly paths against a byte oracle, stride-prefetch bounds (never past the
blob end, never across the publish frontier), watch-warmer behavior under GC
and snapshot pins, and the cross-writev metadata coalescing of the
``write_async`` window.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import Cluster, NodeKey, PrefetchConfig

PAGE = 64


def make_cluster(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw)


def page(fill, nbytes=PAGE):
    return np.full(nbytes, fill, np.uint8)


# --------------------- structural overlap (the tentpole) ----------------------


class _OverlapHarness:
    """Block one metadata shard's final-level (leaf) response and count
    provider ``get_pages`` calls issued while it is blocked."""

    def __init__(self, cluster, blocked_sid=0):
        self.blocked = threading.Event()
        self.release = threading.Event()
        self.get_pages_calls = []
        shard = cluster.metadata.shards[blocked_sid]
        real = shard.get_many

        def blocking_get_many(keys):
            if any(k.size == 1 for k in keys):
                self.blocked.set()
                assert self.release.wait(10), "harness never released"
            return real(keys)

        shard.get_many = blocking_get_many
        for provider in cluster.provider_manager.providers():
            orig = provider.get_pages

            def counting(keys, _orig=orig, _pid=provider.provider_id):
                self.get_pages_calls.append(_pid)
                return _orig(keys)

            provider.get_pages = counting


def _leaf_shard_spread(cluster, blob, version, n_pages):
    keys = [NodeKey(blob, version, o, 1) for o in range(n_pages)]
    return {cluster.metadata._home(k) for k in keys}


def test_get_pages_issued_before_final_traversal_level_completes():
    """Tentpole, asserted structurally: with one shard's leaf batch stalled,
    the leaves already delivered by the OTHER shard must have get_pages
    fetches in flight — data transfer overlaps the rest of the level."""
    cluster = make_cluster(n_metadata_providers=2, max_workers=8)
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(16 * PAGE, PAGE)
    payload = (np.arange(16 * PAGE) % 251).astype(np.uint8)
    handle.write(payload.copy(), 0)
    # the write's leaf keys must span both shards, or there is nothing to
    # overlap (hash placement is deterministic, so assert the premise)
    assert _leaf_shard_spread(cluster, handle.blob_id, 1, 16) == {0, 1}

    harness = _OverlapHarness(cluster, blocked_sid=0)
    result = {}
    t = threading.Thread(
        target=lambda: result.update(data=handle.read(0, 16 * PAGE).data)
    )
    t.start()
    try:
        assert harness.blocked.wait(10)
        # shard 0's final-level RPC is stalled -> the level has NOT completed;
        # poll for the fetches streamed from shard 1's leaves
        deadline = time.monotonic() + 5
        while not harness.get_pages_calls and time.monotonic() < deadline:
            time.sleep(0.005)
        assert harness.get_pages_calls, (
            "no get_pages issued while the final traversal level was stalled"
        )
    finally:
        harness.release.set()
        t.join(10)
    np.testing.assert_array_equal(result["data"], payload)
    cluster.close()


def test_sync_read_keeps_the_phased_barrier():
    """A/B contrast: a ``sync_read`` session issues NO page fetch until the
    full traversal (including the stalled shard) completes."""
    cluster = make_cluster(n_metadata_providers=2, max_workers=8)
    sess = cluster.session(cache_bytes=0, sync_read=True)
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(page(5, 16 * PAGE), 0)
    assert _leaf_shard_spread(cluster, handle.blob_id, 1, 16) == {0, 1}

    harness = _OverlapHarness(cluster, blocked_sid=0)
    t = threading.Thread(target=lambda: handle.read(0, 16 * PAGE))
    t.start()
    try:
        assert harness.blocked.wait(10)
        time.sleep(0.1)  # give a broken barrier time to leak a fetch
        assert not harness.get_pages_calls
    finally:
        harness.release.set()
        t.join(10)
    cluster.close()


def test_stream_and_phased_reads_are_identical():
    """Equivalence: the streaming pipeline and the phased baseline return
    byte-identical results for a pile of awkward segments."""
    cluster = make_cluster()
    streamed = cluster.session(cache_bytes=0)
    phased = cluster.session(cache_bytes=0, sync_read=True)
    h = streamed.create(64 * PAGE, PAGE)
    rng = np.random.default_rng(42)
    # sparse writes leave implicit-zero holes for the traversal to mark
    for off in (0, 7, 23, 40):
        h.write(rng.integers(1, 255, 3 * PAGE, dtype=np.uint8), off * PAGE)
    segs = [(0, 64 * PAGE), (PAGE // 2, 5 * PAGE), (9 * PAGE, 3),
            (22 * PAGE + 1, 4 * PAGE), (63 * PAGE, 2 * PAGE), (5, 0)]
    a = h.readv(segs)
    b = phased.open(h.blob_id).readv(segs)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    cluster.close()


# ------------------------------ assembly paths --------------------------------


def test_assembly_matches_byte_oracle():
    """The np.empty + explicit-zero-fill and aligned-concatenate assembly
    paths against a flat byte oracle, including unwritten (implicit zero)
    gaps that an uninitialized buffer would expose as garbage."""
    cluster = make_cluster()
    sess = cluster.session(cache_bytes=0)
    h = sess.create(32 * PAGE, PAGE)
    oracle = np.zeros(32 * PAGE, np.uint8)
    rng = np.random.default_rng(7)
    for off_page, n_pages in ((2, 3), (10, 1), (17, 6)):
        buf = rng.integers(1, 255, n_pages * PAGE, dtype=np.uint8)
        oracle[off_page * PAGE:(off_page + n_pages) * PAGE] = buf
        h.write(buf.copy(), off_page * PAGE)
    cases = [
        (0, 32 * PAGE),          # aligned multi-page, holes included
        (2 * PAGE, 3 * PAGE),    # aligned multi-page, fully present
        (PAGE, PAGE),            # single whole page, implicit zero
        (2 * PAGE + 5, PAGE),    # unaligned, single-page covered
        (PAGE + 1, 3 * PAGE),    # unaligned spanning hole + data
        (31 * PAGE + 7, 5 * PAGE),  # clamped at blob end
    ]
    outs = h.readv(cases)
    for (off, size), got in zip(cases, outs):
        size = min(size, 32 * PAGE - off)
        np.testing.assert_array_equal(got, oracle[off:off + size])
    cluster.close()


def test_full_aligned_segment_avoids_per_page_loop_output():
    """An aligned multi-page read returns one fresh contiguous buffer (the
    concatenate path), never a view of a stored page."""
    cluster = make_cluster()
    sess = cluster.session(cache_bytes=0)
    h = sess.create(8 * PAGE, PAGE)
    h.write(page(9, 8 * PAGE), 0)
    out = h.read(0, 4 * PAGE).data
    assert out.flags.owndata and out.size == 4 * PAGE
    np.testing.assert_array_equal(out, page(9, 4 * PAGE))
    cluster.close()


# ------------------------------ stride prefetch -------------------------------


def _stream_session(cluster, **cfg):
    return cluster.session(
        cache_bytes=0,
        prefetch=PrefetchConfig(**{"min_run": 2, "window_pages": 8,
                                   "max_inflight": 2, **cfg}),
    )


def test_stride_prefetch_fills_ahead_and_serves_hits():
    cluster = make_cluster(shared_cache_bytes=64 << 20)
    sess = _stream_session(cluster)
    h = sess.create(64 * PAGE, PAGE)
    h.write(page(3, 64 * PAGE), 0)
    cluster.gc(h.blob_id, [1])  # drop nothing, but keep things honest
    stats = sess.stats
    for i in range(3):  # third sequential read arms the detector
        h.read(i * 2 * PAGE, 2 * PAGE)
    assert sess.prefetcher.issued >= 1
    assert sess.prefetcher.wait_idle(10)
    # the next window is now RAM: no provider or metadata traffic at all
    before_rounds = cluster.stats.data_rounds
    h0 = stats.cache_hits
    h.read(6 * PAGE, 2 * PAGE)
    assert stats.cache_hits - h0 == 2
    assert cluster.stats.data_rounds == before_rounds
    cluster.close()


def test_stride_prefetch_never_past_blob_end():
    cluster = make_cluster(shared_cache_bytes=64 << 20)
    sess = _stream_session(cluster, window_pages=32)
    h = sess.create(16 * PAGE, PAGE)
    h.write(page(1, 16 * PAGE), 0)
    # sequential sweep right up to the last page: readahead must clamp
    for i in range(8):
        h.read(i * 2 * PAGE, 2 * PAGE)
    assert sess.prefetcher.wait_idle(10)
    shared = cluster.shared_cache
    assert all(key[2] < 16 for key in shared._lru)  # no page past the end
    cluster.close()


def test_stride_prefetch_stays_behind_publish_frontier():
    """Readahead only ever targets the version the reader resolved — an
    unpublished concurrent write can never be pulled into any cache tier by
    the prefetcher (the PR 4 coherence invariant, restated for prefetch)."""
    cluster = make_cluster(shared_cache_bytes=64 << 20)
    sess = _stream_session(cluster)
    h = sess.create(64 * PAGE, PAGE)
    h.write(page(1, 64 * PAGE), 0)  # v1 published

    # v2 assigned but unpublished: its data put is stalled on a provider
    provider = cluster.provider_manager.get_provider(0)
    started, release = threading.Event(), threading.Event()
    real_put = provider.put_pages

    def blocked_put(items):
        started.set()
        assert release.wait(10)
        return real_put(items)

    provider.put_pages = blocked_put
    writer = cluster.session(cache_bytes=0)
    t = threading.Thread(
        target=lambda: writer.open(h.blob_id).write(page(2, 4 * PAGE), 0)
    )
    t.start()
    assert started.wait(10)
    try:
        for i in range(4):  # stride reads of v1 while v2 is in flight
            h.read(i * 2 * PAGE, 2 * PAGE, version=1)
        assert sess.prefetcher.wait_idle(10)
        cached = set(cluster.shared_cache.cached_versions(h.blob_id))
        assert 2 not in cached  # the unpublished frontier stayed unpolluted
        assert sess.prefetcher.issued >= 1
    finally:
        release.set()
        t.join(10)
    cluster.close()


def test_stride_prefetch_inflight_bound_drops_not_blocks():
    cluster = make_cluster(shared_cache_bytes=64 << 20,
                           page_service_seconds=0.05)
    sess = _stream_session(cluster, max_inflight=1, window_pages=4)
    h = sess.create(64 * PAGE, PAGE)
    h.write(page(1, 64 * PAGE), 0)
    t0 = time.monotonic()
    for i in range(6):
        h.read(i * PAGE, PAGE)
    # the reads themselves paid service time, but nothing stacked behind a
    # queue of readahead tasks (dropped observations are counted instead)
    assert sess.prefetcher.issued + sess.prefetcher.skipped_inflight >= 1
    assert time.monotonic() - t0 < 5
    assert sess.prefetcher.wait_idle(10)
    cluster.close()


# ------------------------------- watch warmer ---------------------------------


def test_watch_warmer_warms_fresh_version_for_cold_detectors():
    cluster = make_cluster(shared_cache_bytes=64 << 20)
    sess = cluster.session(cache_bytes=0)
    h = sess.create(32 * PAGE, PAGE)
    warmer = cluster.warm_on_publish(h.blob_id, top_pages=32)
    h.write(page(4, 32 * PAGE), 0)
    assert warmer.wait_warmed(1, timeout=10)
    detector = cluster.session(cache_bytes=0)
    got = detector.open(h.blob_id).read(0, 32 * PAGE).data
    np.testing.assert_array_equal(got, page(4, 32 * PAGE))
    assert detector.stats.cache_hits == 32  # first read fully warm
    assert detector.stats.cache_misses == 0
    assert warmer.pages_warmed == 32
    cluster.close()


def test_watch_warmer_uses_balancer_heat():
    from repro.core import BalancerConfig

    cluster = make_cluster(
        shared_cache_bytes=64 << 20,
        balancer_config=BalancerConfig(hot_threshold=2, check_interval=1000),
    )
    sess = cluster.session(cache_bytes=0)
    h = sess.create(32 * PAGE, PAGE)
    # heat pages 5-6 across two versions (cache keys are per version, so
    # each versioned read is a real provider fetch feeding the balancer)
    h.write(page(1, 32 * PAGE), 0)
    h.readv([(5 * PAGE, 2 * PAGE)], version=1)
    h.write(page(2, 32 * PAGE), 0)
    h.readv([(5 * PAGE, 2 * PAGE)], version=2)
    hot = cluster.replica_balancer.hottest_page_offsets(h.blob_id, 2)
    assert set(hot) == {5, 6}
    warmer = cluster.warm_on_publish(h.blob_id, top_pages=2)
    h.write(page(3, 32 * PAGE), 0)  # v3: fresh frame
    assert warmer.wait_warmed(3, timeout=10)
    assert {k[2] for k in cluster.shared_cache._lru if k[1] == 3} == {5, 6}
    cluster.close()


def test_watch_warmer_respects_gc_and_snapshot_pins():
    cluster = make_cluster(shared_cache_bytes=64 << 20)
    sess = cluster.session(cache_bytes=0)
    h = sess.create(16 * PAGE, PAGE)
    warmer = cluster.warm_on_publish(h.blob_id, top_pages=16)
    h.write(page(1, 16 * PAGE), 0)
    assert warmer.wait_warmed(1, timeout=10)
    pin = h.at(1)  # snapshot pin on the warmed version
    h.write(page(2, 16 * PAGE), 0)
    assert warmer.wait_warmed(2, timeout=10)
    # GC keeping only v2 must spare the pinned v1 — including its warm pages
    cluster.gc(h.blob_id, keep_versions=[2])
    assert 1 in cluster.shared_cache.cached_versions(h.blob_id)
    np.testing.assert_array_equal(pin.read(0, 16 * PAGE), page(1, 16 * PAGE))
    # release the pin: the next GC purges the collected version's warm pages
    pin.release()
    cluster.gc(h.blob_id, keep_versions=[2])
    assert 1 not in cluster.shared_cache.cached_versions(h.blob_id)
    np.testing.assert_array_equal(
        sess.open(h.blob_id).read(0, 16 * PAGE).data, page(2, 16 * PAGE)
    )
    cluster.close()


def test_watch_warmer_frame_stride_skips_mid_frame_versions():
    cluster = make_cluster(shared_cache_bytes=64 << 20)
    sess = cluster.session(cache_bytes=0)
    h = sess.create(16 * PAGE, PAGE)
    warmer = cluster.warm_on_publish(h.blob_id, top_pages=16, frame_versions=4)
    for v in range(4):  # one frame = 4 region patches
        h.write(page(v + 1, 4 * PAGE), v * 4 * PAGE)
    assert warmer.wait_warmed(4, timeout=10)
    assert set(warmer.warmed_versions()) == {4}  # only the frame boundary
    cluster.close()


# --------------------- cross-writev metadata coalescing -----------------------


def test_async_writes_coalesce_metadata_rounds():
    """Satellite: small writes streaming through the write_async window share
    aggregated shard rounds via group commit instead of paying one round
    each; results stay byte-identical to looped writes."""
    cluster = make_cluster(metadata_latency_seconds=0.05, max_workers=16)
    sess = cluster.session(cache_bytes=0, max_inflight_writes=8)
    h = sess.create(64 * PAGE, PAGE)
    futures = [h.write_async(page(i + 1), i * PAGE) for i in range(8)]
    versions = [f.result() for f in futures]
    assert sorted(versions) == list(range(1, 9))
    # 8 concurrent writes against coalesce_max_rounds round slots: with a
    # 50ms RTT the overflow writes queue and share group commits, so the
    # whole burst costs strictly fewer rounds than one per write
    assert cluster.metadata.coalesced_rounds < 8
    reader = cluster.session(cache_bytes=0)
    got = reader.open(h.blob_id).read(0, 8 * PAGE).data
    np.testing.assert_array_equal(
        got, np.concatenate([page(i + 1) for i in range(8)])
    )
    cluster.close()


def test_coalesced_flush_isolates_shard_failures():
    """A shard failure inside a group commit fails exactly the writes with
    nodes on that shard — not every write that happened to share the round."""
    from repro.core.dht import MetadataDHT, ProviderFailed
    from repro.core.segment_tree import TreeNode

    dht = MetadataDHT(2)
    a = TreeNode(NodeKey(0, 1, 0, 1), page=(0, 0))
    b = None  # find a key deterministically homed on the OTHER shard
    for off in range(8):
        cand = TreeNode(NodeKey(0, 2, off, 1), page=(0, 1))
        if dht._home(cand.key) != dht._home(a.key):
            b = cand
            break
    assert b is not None
    dht.fail_shard(dht._home(a.key))
    fa = dht.put_nodes_coalesced([a])[0]
    fb = dht.put_nodes_coalesced([b])[0]
    with pytest.raises(ProviderFailed):
        fa.result(timeout=10)  # homed on the failed shard
    fb.result(timeout=10)  # shared a round (or not) — still durable
    assert dht.shards[dht._home(b.key)].get(b.key) is not None
    dht.close()
