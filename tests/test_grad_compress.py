"""Int8 error-feedback gradient compression: correctness + EF convergence."""

import os

import numpy as np
import pytest

# this test builds a multi-device mesh: needs the forced host device count
if "XLA_FLAGS" not in os.environ or "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.axisinfo import AxisInfo
from repro.train.grad_compress import compressed_pod_mean, ef_init

pytestmark = pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")


def make_axis_info():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    return AxisInfo(mesh, batch_axes=("pod", "data"), model_axis="model")


def test_compressed_mean_close_to_exact():
    ai = make_axis_info()
    grads = {
        "w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8),
        "b": jnp.ones((4,)) * 0.5,
    }
    specs = {"w": P(), "b": P()}
    err = ef_init(grads)

    out, new_err = jax.jit(
        lambda g, e: compressed_pod_mean(g, e, ai, specs)
    )(grads, err)
    # grads identical across pods -> mean == input, up to int8 quantization
    for k in grads:
        scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(grads[k]),
                                   atol=scale * 1.01)
        # error feedback holds exactly the quantization residual
        np.testing.assert_allclose(
            np.asarray(new_err[k]), np.asarray(grads[k] - out[k]), atol=1e-6
        )


def test_error_feedback_unbiased_over_steps():
    """Constant gradient: with EF the MEAN of compressed outputs converges to
    the true gradient (bias -> 0); without EF the bias persists."""
    ai = make_axis_info()
    g = {"w": jnp.full((16,), 0.3017)}
    specs = {"w": P()}
    err = ef_init(g)
    fn = jax.jit(lambda gg, e: compressed_pod_mean(gg, e, ai, specs))
    outs = []
    for _ in range(50):
        out, err = fn(g, err)
        outs.append(np.asarray(out["w"]))
    mean_est = np.mean(outs, axis=0)
    np.testing.assert_allclose(mean_est, 0.3017, rtol=2e-3)


def test_single_pod_is_identity():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ai = AxisInfo(mesh, batch_axes=("data",), model_axis="model")
    g = {"w": jnp.arange(8.0)}
    err = ef_init(g)
    out, err2 = compressed_pod_mean(g, err, ai, {"w": P()})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))

# ---- distributed training on the 8 forced host devices ----------------------
def test_distributed_train_smoke_and_elastic_reshard():
    """Train a smoke model on an (4 data × 2 model) mesh; checkpoint; restore
    onto a DIFFERENT mesh shape (8×1) — elastic restart with resharding."""
    import numpy as np
    from jax.sharding import NamedSharding

    from repro.launch.train import train
    from repro.parallel import sharding as shd
    from repro.models.lm import build_model
    from repro.configs import get_config
    from repro.launch.mesh import make_axis_info

    out = train("llama3_2-1b", smoke=True, steps=6, batch=8, seq=32,
                model_parallel=2, checkpoint_every=3, lr=1e-3)
    assert np.isfinite(out["losses"]).all()

    # restore the step-6 checkpoint onto a different topology
    cfg = get_config("llama3_2-1b").smoke()
    model = build_model(cfg)
    mesh2 = jax.make_mesh((8, 1), ("data", "model"))
    ai2 = make_axis_info(mesh2)
    params_t, axes = model.init(jax.random.PRNGKey(0))
    p_shard = shd.param_shardings(params_t, axes, cfg, ai2)
    state = out["checkpointer"].restore(
        6, shardings={"params": p_shard, "opt": {"m": p_shard, "v": p_shard,
                                                 "step": NamedSharding(mesh2, P())}}
    )
    # restored params equal the in-memory final params, bit-exact
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distributed_decode_paged_pool_sharded():
    """decode_step under a real mesh: page pool striped over (data, model),
    output must match the single-device run."""
    import numpy as np
    from repro.configs import get_config
    from repro.models.lm import build_model
    from repro.launch.mesh import make_axis_info
    from repro.launch.specs import concrete_batch
    from repro.parallel import sharding as shd

    cfg = get_config("llama3_2-1b").smoke()
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, 4, 16, "prefill")

    logits1, cache1 = jax.jit(lambda p, b: model.prefill(p, b, None))(params, batch)
    toks = jnp.argmax(logits1[:, : cfg.vocab_size], -1).astype(jnp.int32)
    ref_logits, _ = jax.jit(lambda p, c, t: model.decode_step(p, c, t, None))(
        params, cache1, toks
    )

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ai = make_axis_info(mesh)
    pad = ai.n_page_shards
    # distribute the single-device prefill cache: pad the pool page count to
    # a multiple of the page-shard count, keep tables (pad pages unreferenced)
    kv1 = cache1["kv"]
    n_src = kv1["pool_k"].shape[1]
    n_tgt = -(-n_src // pad) * pad
    padw = [(0, 0), (0, n_tgt - n_src)] + [(0, 0)] * 3
    cache2 = {
        "kv": {
            "pool_k": jnp.pad(kv1["pool_k"], padw),
            "pool_v": jnp.pad(kv1["pool_v"], padw),
            "tables": kv1["tables"],
            "page_pos": kv1["page_pos"],
        },
        "lengths": cache1["lengths"],
    }
    cache_sh = shd.cache_shardings(jax.eval_shape(lambda: cache2), cfg, ai)
    cache2 = jax.tree.map(lambda x, s: jax.device_put(x, s), cache2, cache_sh)

    with mesh:
        got, _ = jax.jit(lambda p, c, t: model.decode_step(p, c, t, ai))(
            params, cache2, toks
        )
    # bf16 page pools: distributed split-K accumulation reorders sums
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                               rtol=5e-3, atol=5e-3)
