"""Seeded lint fixture: forbidden-API rules.

Parsed (never imported) by tests/test_analysis.py — must be flagged
``facade-import``, ``fulfill-without-plan`` and ``direct-store-mutation``.
"""

from repro.core.blob import BlobStore  # EXPECT facade-import


class SneakyFiller:
    def backdoor_fill(self, cache, key, page):
        cache.fulfill(key, page)  # EXPECT fulfill-without-plan

    def honest_fill(self, cache, keys, pages):
        plan = cache.plan(keys)
        for key in plan.to_fetch:
            cache.fulfill(key, pages[key])  # fine: planned first

    def poke_provider(self, provider, page):
        provider._pages[0] = page  # EXPECT direct-store-mutation

    def drop_node(self, shard, key):
        shard._nodes.pop(key)  # EXPECT direct-store-mutation
