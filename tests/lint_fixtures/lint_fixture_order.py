"""Seeded lint fixture: acquisition edges that break the declared hierarchy.

Parsed (never imported) by tests/test_analysis.py — the reversed nesting must
be flagged ``lock-order`` and the unregistered name ``undeclared-lock``.
"""

from repro.analysis.lockwatch import make_lock


class BackwardNesting:
    def __init__(self):
        self._cache_lock = make_lock("PageCache._lock")  # level 5
        self._guard = make_lock("Cluster._gc_guard")  # level 1
        self._stats_lock = make_lock("TrafficStats._lock")  # level 5
        self._mystery = make_lock("Mystery._lock")  # EXPECT undeclared-lock

    def reversed_pair(self):
        with self._cache_lock:
            with self._guard:  # EXPECT lock-order (5 -> 1)
                pass

    def same_level_pair(self):
        with self._cache_lock:
            with self._stats_lock:  # EXPECT lock-order (5 -> 5)
                pass

    def correct_pair(self):
        with self._guard:
            with self._cache_lock:  # fine: 1 -> 5
                pass
