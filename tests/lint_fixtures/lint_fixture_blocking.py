"""Seeded lint fixture: blocking calls inside strict critical sections.

Parsed (never imported) by tests/test_analysis.py — each marked line must be
flagged by the ``blocking-under-lock`` rule.
"""

import threading
import time


class SleepyCache:
    def __init__(self):
        self._fill_lock = threading.Lock()

    def fill(self, fetch):
        with self._fill_lock:
            time.sleep(0.01)  # EXPECT blocking-under-lock
            return fetch()

    def fill_future(self, pool, fetch):
        with self._fill_lock:
            fut = pool.submit(fetch)
            return fut.result()  # EXPECT blocking-under-lock

    def drain(self, worker):
        with self._fill_lock:
            worker.join()  # EXPECT blocking-under-lock

    def fill_allowed(self, fetch):
        with self._fill_lock:
            time.sleep(0.01)  # lint: allow(blocking-under-lock)
            return fetch()
