"""Cluster / Session / BlobHandle API tests: snapshot pinning, the shared
cache tier and its publish-frontier gating, version-watch subscriptions, GC
coherence across session caches, and the deprecated ``BlobStore`` facade.
"""

import threading

import numpy as np
import pytest

from repro.core import BlobStore, Cluster

PAGE = 64


def make_cluster(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 1 << 20)
    return Cluster(**kw)


def page(fill, nbytes=PAGE):
    return np.full(nbytes, fill, np.uint8)


# ------------------------------ snapshots -------------------------------------


def test_snapshot_pins_version_across_later_writes():
    cluster = make_cluster()
    handle = cluster.session().create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)  # v1
    snap = handle.snapshot()
    assert snap.version == 1
    handle.write(page(2, 8 * PAGE), 0)  # v2
    handle.write(page(3, 8 * PAGE), 0)  # v3
    # the pinned view is immutable: later writes never leak in
    assert (snap.read(0, 8 * PAGE) == 1).all()
    assert (handle.read(0, PAGE).data == 3).all()
    cluster.close()


def test_snapshot_pin_survives_gc_of_other_versions():
    """GC with keep_versions NOT including the snapshot's version must still
    keep the pinned version fully readable (the pin is an implicit keep)."""
    cluster = make_cluster()
    handle = cluster.session().create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)  # v1
    snap = handle.at(1)
    handle.write(page(2, 8 * PAGE), 0)  # v2 rewrites everything
    nodes, pages = cluster.gc(handle.blob_id, keep_versions=[2])
    assert (nodes, pages) == (0, 0)  # v1 was pinned: nothing collectable
    assert (snap.read(0, 8 * PAGE) == 1).all()
    # releasing the pin makes v1 collectable
    snap.release()
    assert not snap.pinned
    nodes, pages = cluster.gc(handle.blob_id, keep_versions=[2])
    assert pages == 8  # v1's pages die now
    with pytest.raises(KeyError):
        handle.read(0, 8 * PAGE, version=1)
    cluster.close()


def test_snapshot_context_manager_releases_pin():
    cluster = make_cluster()
    handle = cluster.session().create(4 * PAGE, PAGE)
    handle.write(page(5, 4 * PAGE), 0)
    with handle.snapshot() as snap:
        assert cluster.pinned_versions(handle.blob_id) == {1}
        assert (snap.read(0, PAGE) == 5).all()
    assert cluster.pinned_versions(handle.blob_id) == set()
    cluster.close()


def test_snapshot_rereads_are_lock_free():
    """Repeated reads through a snapshot never consult the version manager:
    the serialized actor is paid once, at snapshot creation."""
    cluster = make_cluster()
    handle = cluster.session().create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)
    vm = cluster.version_manager
    calls = []
    orig = vm.resolve_read_version
    vm.resolve_read_version = lambda *a: (calls.append(a), orig(*a))[1]
    try:
        snap = handle.snapshot()  # ONE resolve
        for _ in range(5):
            snap.readv([(0, 2 * PAGE), (4 * PAGE, PAGE)])
    finally:
        vm.resolve_read_version = orig
    assert len(calls) == 1
    cluster.close()


def test_at_rejects_unpublished_and_abandoned_versions():
    cluster = make_cluster()
    handle = cluster.session().create(4 * PAGE, PAGE)
    with pytest.raises(ValueError, match="not yet published"):
        handle.at(1)
    cluster.close()


# ---------------------------- shared cache tier -------------------------------


def test_shared_tier_hit_accounting_across_sessions():
    """Session A's cold read fills the shared tier; session B's identical
    read is pure RAM hits — per-session ledgers attribute each side, the
    cluster ledger aggregates both."""
    cluster = make_cluster()
    writer = cluster.session()
    handle = writer.create(8 * PAGE, PAGE)
    handle.write(np.arange(8 * PAGE, dtype=np.uint8), 0)

    a = cluster.session(cache_bytes=0)
    b = cluster.session(cache_bytes=0)
    cluster.stats.reset()
    a.open(handle.blob_id).read(0, 8 * PAGE)  # cold: fills the shared tier
    assert a.stats.cache_misses == 8 and a.stats.cache_hits == 0
    b.open(handle.blob_id).read(0, 8 * PAGE)  # pure shared-tier hits
    assert b.stats.cache_hits == 8 and b.stats.cache_misses == 0
    assert b.stats.data_rounds == 0  # no provider traffic at all
    # cluster ledger = sum of the sessions'
    assert cluster.stats.cache_hits == a.stats.cache_hits + b.stats.cache_hits
    assert cluster.stats.cache_misses == a.stats.cache_misses + b.stats.cache_misses
    assert b.cache_hit_rate == 1.0
    cluster.close()


def test_shared_tier_single_flight_across_sessions():
    """Concurrent cold readers in DIFFERENT sessions collapse to one provider
    fetch per page (node-wide single-flight at the shared tier)."""
    from repro.core.provider import DataProvider

    cluster = make_cluster(max_workers=32)
    writer = cluster.session(cache_bytes=0)
    handle = writer.create(16 * PAGE, PAGE)
    payload = np.arange(16 * PAGE, dtype=np.uint8) % 251
    handle.write(payload, 0)

    fetched_keys = []
    count_lock = threading.Lock()
    real_get_pages = DataProvider.get_pages

    def counting_get_pages(self, page_keys):
        with count_lock:
            fetched_keys.extend(page_keys)
        threading.Event().wait(0.05)  # keep readers genuinely overlapped
        return real_get_pages(self, page_keys)

    n_readers = 8
    barrier = threading.Barrier(n_readers)
    results = [None] * n_readers
    errors = []

    def reader(i):
        try:
            mine = cluster.session(cache_bytes=0).open(handle.blob_id)
            barrier.wait()
            results[i] = mine.read(0, 16 * PAGE, version=1).data
        except Exception as e:  # pragma: no cover
            errors.append(e)

    DataProvider.get_pages = counting_get_pages
    try:
        threads = [threading.Thread(target=reader, args=(i,)) for i in range(n_readers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        DataProvider.get_pages = real_get_pages

    assert not errors
    for r in results:
        np.testing.assert_array_equal(r, payload)
    assert len(fetched_keys) == 16  # one fetch per page for 8 sessions
    assert len(set(fetched_keys)) == 16
    cluster.close()


def test_own_unpublished_writes_hit_private_tier_only():
    """Write-through lands in the writer's PRIVATE cache under its assigned
    versions; the shared tier stays empty until a validated read fills it."""
    cluster = make_cluster()
    writer = cluster.session()
    handle = writer.create(8 * PAGE, PAGE)
    handle.write(page(1, 4 * PAGE), 0)
    assert writer.cache.cached_versions(handle.blob_id) == [1]
    assert cluster.shared_cache.cached_versions(handle.blob_id) == []
    # the writer's own re-read is RAM (private tier), no provider traffic
    cluster.stats.reset()
    handle.read(0, 4 * PAGE, version=1)
    assert cluster.stats.data_rounds == 0
    assert writer.stats.cache_hits >= 4
    cluster.close()


def test_unpublished_writes_invisible_across_sessions():
    """The acceptance invariant: a cross-session read of an unpublished
    version is impossible by construction — the read path rejects it at the
    publish frontier, and the shared tier never holds unpublished pages."""
    cluster = make_cluster()
    writer = cluster.session()
    handle = writer.create(8 * PAGE, PAGE)
    blob = handle.blob_id
    # wedge publication: v1 assigned to a writer that never reports success
    cluster.version_manager.assign_version(blob, 0, 1)
    v2 = None
    # v2's writev completes fully but cannot publish behind the v1 hole
    v2 = handle.writev([(0, page(9, 8 * PAGE))])[0]
    assert v2 == 2
    assert handle.latest_published() == 0
    # the writer holds its own pages in its private cache...
    assert writer.cache.cached_versions(blob) == [v2]
    # ...but another session can neither read the version nor find any trace
    # of it in the shared tier
    other = cluster.session().open(blob)
    with pytest.raises(ValueError, match="not yet published"):
        other.read(0, PAGE, version=v2)
    with pytest.raises(ValueError, match="not yet published"):
        other.at(v2)
    assert cluster.shared_cache.cached_versions(blob) == []
    # once the frontier advances past the hole, the same read succeeds
    cluster.version_manager.abandon(blob, [1])
    assert other.read(0, PAGE, version=v2).data[0] == 9
    cluster.close()


# ------------------------------ GC coherence ----------------------------------


def test_gc_purges_shared_tier_and_every_session_cache():
    cluster = make_cluster()
    writer = cluster.session()
    handle = writer.create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)  # v1 (write-through: writer cache)
    handle.write(page(2, 8 * PAGE), 0)  # v2
    a = cluster.session()
    b = cluster.session()
    for sess in (a, b):
        h = sess.open(handle.blob_id)
        h.read(0, 8 * PAGE, version=1)  # fills shared tier + touches session
        h.read(0, 8 * PAGE, version=2)
    assert cluster.shared_cache.cached_versions(handle.blob_id) == [1, 2]
    cluster.gc(handle.blob_id, keep_versions=[2])
    assert cluster.shared_cache.cached_versions(handle.blob_id) == [2]
    for sess in (writer, a, b):
        assert 1 not in sess.cache.cached_versions(handle.blob_id)
    # v2 still fully readable everywhere
    assert (a.open(handle.blob_id).read(0, 8 * PAGE, version=2).data == 2).all()
    cluster.close()


def test_write_async_rejected_on_closed_session():
    """A closed session's writer pool is gone and GC no longer purges its
    cache — silently resurrecting the pool would leak threads."""
    cluster = make_cluster()
    sess = cluster.session()
    handle = sess.create(4 * PAGE, PAGE)
    sess.close()
    with pytest.raises(RuntimeError, match="closed session"):
        handle.write_async(page(1), 0)
    cluster.close()


def test_sessions_draw_distinct_replica_choice_streams():
    """N sessions seeded identically would sample the same replica pair at
    every draw and re-herd hot pages; the streams must diverge."""
    cluster = make_cluster()
    streams = [
        tuple(
            tuple(sess._rng.sample(range(8), 2))
            for sess in [cluster.session()]
            for _ in range(8)
        )
        for _ in range(4)
    ]
    assert len(set(streams)) == len(streams)
    cluster.close()


def test_closed_session_cache_not_purged_but_forgotten():
    cluster = make_cluster()
    sess = cluster.session()
    assert sess in cluster.sessions()
    sess.close()
    assert sess not in cluster.sessions()
    sess.close()  # idempotent
    cluster.close()


# ------------------------------ version watch ---------------------------------


def test_watch_delivers_versions_in_order_under_concurrent_publishes():
    """Wakeup ordering: N sessions publish concurrently; a watcher receives
    the dense version sequence 1..N strictly in order."""
    cluster = make_cluster(n_data_providers=8, max_workers=16)
    blob = cluster.alloc(32 * PAGE, PAGE)
    watch = cluster.session().open(blob).watch()
    n_writers = 8
    barrier = threading.Barrier(n_writers)

    def writer(i):
        h = cluster.session(cache_bytes=0).open(blob)
        barrier.wait()
        h.write(page(i + 1), (i % 32) * PAGE)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(n_writers)]
    for t in threads:
        t.start()
    delivered = [watch.next(timeout=10) for _ in range(n_writers)]
    for t in threads:
        t.join()
    assert delivered == list(range(1, n_writers + 1))  # dense AND ordered
    assert watch.next(timeout=0.05) is None  # nothing further
    cluster.close()


def test_watch_wakes_mid_wait_and_times_out_cleanly():
    cluster = make_cluster()
    handle = cluster.session().create(4 * PAGE, PAGE)
    watch = handle.watch()
    assert watch.next(timeout=0.05) is None  # nothing published yet

    def later():
        threading.Event().wait(0.1)
        handle.write(page(1), 0)

    t = threading.Thread(target=later)
    t.start()
    assert watch.next(timeout=10) == 1  # woken by the publish, not polling
    t.join()
    cluster.close()


def test_watch_skips_abandoned_holes():
    cluster = make_cluster()
    handle = cluster.session().create(8 * PAGE, PAGE)
    blob = handle.blob_id
    vm = cluster.version_manager
    vm.assign_version(blob, 0, 1)  # v1: writer will die
    v2 = None
    watch = handle.watch()
    v2 = handle.writev([(4 * PAGE, page(2))])[0]  # v2 completes, waits on v1
    vm.abandon(blob, [1])  # v1 becomes a hole; v2 publishes
    assert watch.next(timeout=5) == v2  # the hole is never delivered
    assert watch.drain() == []
    cluster.close()


def test_watch_drain_collects_backlog_without_blocking():
    cluster = make_cluster()
    handle = cluster.session().create(8 * PAGE, PAGE)
    watch = handle.watch()
    for i in range(3):
        handle.write(page(i + 1), 0)
    assert watch.drain() == [1, 2, 3]
    cluster.close()


def test_wait_for_version_blocks_until_publication():
    cluster = make_cluster()
    handle = cluster.session().create(4 * PAGE, PAGE)
    assert not handle.wait_for_version(1, timeout=0.05)

    def pub():
        handle.write(page(1), 0)

    t = threading.Thread(target=pub)
    t.start()
    assert handle.wait_for_version(1, timeout=10)
    t.join()
    cluster.close()


# ------------------------------ facade compat ---------------------------------


def test_blobstore_facade_smoke():
    """The deprecated entry points keep working, warn on construction, and
    route through the same cluster/session machinery."""
    with pytest.warns(DeprecationWarning, match="BlobStore is deprecated"):
        store = BlobStore(n_data_providers=4, n_metadata_providers=4)
    blob = store.alloc(16 * PAGE, PAGE)
    v1 = store.write(blob, page(1, 2 * PAGE), 0)
    assert v1 == 1
    res = store.read(blob, None, 0, 2 * PAGE)
    assert (res.data == 1).all() and res.latest_published == 1
    vs = store.writev(blob, [(4 * PAGE, page(2)), (8 * PAGE, page(3, 2 * PAGE))])
    assert vs == [2, 3]
    outs = store.readv(blob, None, [(4 * PAGE, PAGE), (8 * PAGE, PAGE)])
    assert outs[0][0] == 2 and outs[1][0] == 3
    fut = store.write_async(blob, page(4), 12 * PAGE)
    assert fut.result() == 4
    store.flush()
    v5 = store.write_unaligned(blob, page(5, 10), 3)
    assert store.read(blob, v5, 3, 10).data[0] == 5
    # old attribute surface still reachable
    assert store.version_manager.latest_published(blob) == v5
    assert store.page_cache is not None and store.replica_balancer is not None
    assert store.stats.data_rounds > 0
    assert store.storage_bytes() > 0
    nodes, pages = store.gc(blob, keep_versions=[v5])
    assert pages > 0
    assert (store.read(blob, None, 0, PAGE).data[:1] == 1).all()
    store.close()


def test_facade_is_one_session_on_a_private_cluster():
    with pytest.warns(DeprecationWarning):
        store = BlobStore(n_data_providers=2, n_metadata_providers=2)
    assert store.cluster.sessions() == [store.session]
    assert store.cluster.shared_cache is None  # pre-split topology
    assert store.page_cache is store.session.cache
    store.close()
