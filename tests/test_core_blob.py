"""Unit + property tests for the faithful blob-store reproduction."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BlobStore,
    ZERO_VERSION,
    compute_border_links,
    count_write_nodes,
)

PAGE = 64  # tiny pages for tests


def make_store(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    return BlobStore(**kw)


def test_alloc_read_zero_version():
    store = make_store()
    blob = store.alloc(16 * PAGE, PAGE)
    res = store.read(blob, None, 0, 16 * PAGE)
    assert res.latest_published == ZERO_VERSION
    assert not res.data.any()  # version 0 is the all-zero string (paper §II)


def test_write_then_read_roundtrip():
    store = make_store()
    blob = store.alloc(16 * PAGE, PAGE)
    payload = np.arange(4 * PAGE, dtype=np.uint8)
    v = store.write(blob, payload, 2 * PAGE)
    assert v == 1
    res = store.read(blob, v, 2 * PAGE, 4 * PAGE)
    np.testing.assert_array_equal(res.data, payload)
    # untouched pages still zero
    assert not store.read(blob, v, 0, 2 * PAGE).data.any()
    assert not store.read(blob, v, 6 * PAGE, 10 * PAGE).data.any()


def test_versioning_snapshots_stay_readable():
    store = make_store()
    blob = store.alloc(8 * PAGE, PAGE)
    a = np.full(2 * PAGE, 7, dtype=np.uint8)
    b = np.full(2 * PAGE, 9, dtype=np.uint8)
    v1 = store.write(blob, a, 0)
    v2 = store.write(blob, b, PAGE)  # overlapping patch
    assert (v1, v2) == (1, 2)
    # v1 unchanged by the later overlapping write (COW)
    np.testing.assert_array_equal(store.read(blob, v1, 0, 2 * PAGE).data, a)
    # v2 = v1 patched by b at offset PAGE
    expect = np.zeros(8 * PAGE, dtype=np.uint8)
    expect[: 2 * PAGE] = a
    expect[PAGE : 3 * PAGE] = b
    np.testing.assert_array_equal(store.read(blob, v2, 0, 8 * PAGE).data, expect[: 8 * PAGE])


def test_read_unpublished_version_fails():
    store = make_store()
    blob = store.alloc(4 * PAGE, PAGE)
    with pytest.raises(ValueError, match="not yet published"):
        store.read(blob, 1, 0, PAGE)


def test_unaligned_write_rejected():
    store = make_store()
    blob = store.alloc(4 * PAGE, PAGE)
    with pytest.raises(ValueError, match="page-aligned"):
        store.write(blob, np.zeros(PAGE, np.uint8), 3)


def test_metadata_sharing_between_versions():
    """COW weaving shares all unmodified subtrees (paper §III.C)."""
    store = make_store()
    blob = store.alloc(1024 * PAGE, PAGE)
    store.write(blob, np.ones(1024 * PAGE, np.uint8), 0)
    n_after_full = store.metadata.total_nodes()
    store.write(blob, np.ones(PAGE, np.uint8), 512 * PAGE)
    n_after_patch = store.metadata.total_nodes()
    # one-page patch creates exactly the root-to-leaf path: log2(1024)+1 nodes
    assert n_after_patch - n_after_full == 11
    assert n_after_patch - n_after_full == count_write_nodes(1024, 512, 1)


def test_page_replication_survives_provider_failure():
    store = make_store(n_data_providers=4, page_replication=2)
    blob = store.alloc(8 * PAGE, PAGE)
    payload = np.arange(8 * PAGE, dtype=np.uint8)
    v = store.write(blob, payload, 0)
    # kill the primary of some page: every page must still be readable
    store.provider_manager.fail_provider(0)
    np.testing.assert_array_equal(store.read(blob, v, 0, 8 * PAGE).data, payload)


def test_metadata_replication_survives_shard_failure():
    store = make_store(n_metadata_providers=4, metadata_replication=2)
    blob = store.alloc(8 * PAGE, PAGE)
    payload = np.arange(8 * PAGE, dtype=np.uint8)
    v = store.write(blob, payload, 0)
    store.metadata.fail_shard(1)
    np.testing.assert_array_equal(store.read(blob, v, 0, 8 * PAGE).data, payload)


def test_gc_keeps_reachable_shared_pages():
    store = make_store()
    blob = store.alloc(16 * PAGE, PAGE)
    base = np.ones(16 * PAGE, np.uint8)
    store.write(blob, base, 0)  # v1
    patch = np.full(PAGE, 5, np.uint8)
    store.write(blob, patch, 4 * PAGE)  # v2 shares 15 pages with v1
    nodes_freed, pages_freed = store.gc(blob, keep_versions=[2])
    assert pages_freed == 1  # only v1's overwritten page dies
    assert nodes_freed > 0  # v1's root path dies
    expect = base.copy()
    expect[4 * PAGE : 5 * PAGE] = patch
    np.testing.assert_array_equal(store.read(blob, 2, 0, 16 * PAGE).data, expect)


def test_elastic_provider_join():
    store = make_store(n_data_providers=2)
    blob = store.alloc(8 * PAGE, PAGE)
    store.write(blob, np.ones(4 * PAGE, np.uint8), 0)
    new_pid = store.add_data_provider()
    store.write(blob, np.ones(4 * PAGE, np.uint8), 4 * PAGE)
    # the new provider picked up load (least-loaded placement)
    assert store.provider_manager.get_provider(new_pid).n_pages > 0


def test_version_manager_recovery_with_orphans():
    store = make_store()
    blob = store.alloc(8 * PAGE, PAGE)
    store.write(blob, np.ones(PAGE, np.uint8), 0)  # v1 complete
    # simulate a writer that got v2 assigned and crashed before reporting
    store.version_manager.assign_version(blob, 2, 1)
    store.write(blob, np.ones(PAGE, np.uint8), 4 * PAGE)  # v3 complete
    from repro.core import VersionManager

    vm2, orphans = VersionManager.recover(store.version_manager.journal)
    assert vm2.latest_published(blob) == 1  # publish stops before the orphan
    assert orphans[blob] == [2]
    # v3 completed: it publishes as soon as the orphan is resolved
    vm2.report_success(blob, 2)
    assert vm2.latest_published(blob) == 3


# ----------------------------- property tests --------------------------------


@st.composite
def patch_sequences(draw):
    n_pages = draw(st.sampled_from([8, 16, 32]))
    n_writes = draw(st.integers(min_value=1, max_value=8))
    writes = []
    for _ in range(n_writes):
        off = draw(st.integers(min_value=0, max_value=n_pages - 1))
        size = draw(st.integers(min_value=1, max_value=n_pages - off))
        fill = draw(st.integers(min_value=1, max_value=255))
        writes.append((off, size, fill))
    return n_pages, writes


@settings(max_examples=30, deadline=None)
@given(patch_sequences())
def test_serializability_reads_equal_prefix_of_patches(seq):
    """Paper §II: READ of version v == successive application of the first v
    patches to the all-zero string — for EVERY published version."""
    n_pages, writes = seq
    store = make_store()
    blob = store.alloc(n_pages * PAGE, PAGE)
    oracle = np.zeros(n_pages * PAGE, dtype=np.uint8)
    snapshots = [oracle.copy()]
    for off, size, fill in writes:
        buf = np.full(size * PAGE, fill, dtype=np.uint8)
        store.write(blob, buf, off * PAGE)
        oracle[off * PAGE : (off + size) * PAGE] = buf
        snapshots.append(oracle.copy())
    for v, snap in enumerate(snapshots):
        got = store.read(blob, v, 0, n_pages * PAGE).data
        np.testing.assert_array_equal(got, snap)


@settings(max_examples=30, deadline=None)
@given(patch_sequences())
def test_border_links_point_to_latest_intersecting_version(seq):
    """compute_border_links must weave to the most recent intersecting patch."""
    n_pages, writes = seq
    intervals = {}

    for v, (off, size, _) in enumerate(writes, start=1):

        def version_of_segment(o, s):
            best = ZERO_VERSION
            for pv, (po, ps) in intervals.items():
                if po < o + s and o < po + ps:
                    best = max(best, pv)
            return best

        links = compute_border_links(n_pages, off, size, version_of_segment)
        for link in links:
            # the missing child never intersects the current patch
            assert not (link.child_offset < off + size and off < link.child_offset + link.child_size)
            assert link.child_version == version_of_segment(link.child_offset, link.child_size)
        intervals[v] = (off, size)


def test_unaligned_write_read_modify_write():
    store = make_store()
    blob = store.alloc(16 * PAGE, PAGE)
    base = np.arange(16 * PAGE, dtype=np.uint8)
    store.write(blob, base, 0)
    patch = np.full(PAGE, 200, np.uint8)
    off = 3 * PAGE + 17  # crosses two pages, unaligned both sides
    v = store.write_unaligned(blob, patch, off)
    expect = base.copy()
    expect[off : off + PAGE] = patch
    got = store.read(blob, v, 0, 16 * PAGE).data
    np.testing.assert_array_equal(got, expect)
    # the pre-patch version is untouched (COW)
    np.testing.assert_array_equal(store.read(blob, v - 1, 0, 16 * PAGE).data, base)
