"""Unit + property tests for the faithful blob-store reproduction, driven
through the layered Cluster / Session / BlobHandle API."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Cluster,
    ZERO_VERSION,
    compute_border_links,
    count_write_nodes,
)

PAGE = 64  # tiny pages for tests


def make_cluster(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw)


def test_alloc_read_zero_version():
    handle = make_cluster().session().create(16 * PAGE, PAGE)
    res = handle.read(0, 16 * PAGE)
    assert res.latest_published == ZERO_VERSION
    assert not res.data.any()  # version 0 is the all-zero string (paper §II)


def test_write_then_read_roundtrip():
    handle = make_cluster().session().create(16 * PAGE, PAGE)
    payload = np.arange(4 * PAGE, dtype=np.uint8)
    v = handle.write(payload, 2 * PAGE)
    assert v == 1
    res = handle.read(2 * PAGE, 4 * PAGE, version=v)
    np.testing.assert_array_equal(res.data, payload)
    # untouched pages still zero
    assert not handle.read(0, 2 * PAGE, version=v).data.any()
    assert not handle.read(6 * PAGE, 10 * PAGE, version=v).data.any()


def test_versioning_snapshots_stay_readable():
    handle = make_cluster().session().create(8 * PAGE, PAGE)
    a = np.full(2 * PAGE, 7, dtype=np.uint8)
    b = np.full(2 * PAGE, 9, dtype=np.uint8)
    v1 = handle.write(a, 0)
    v2 = handle.write(b, PAGE)  # overlapping patch
    assert (v1, v2) == (1, 2)
    # v1 unchanged by the later overlapping write (COW)
    np.testing.assert_array_equal(handle.read(0, 2 * PAGE, version=v1).data, a)
    # v2 = v1 patched by b at offset PAGE
    expect = np.zeros(8 * PAGE, dtype=np.uint8)
    expect[: 2 * PAGE] = a
    expect[PAGE : 3 * PAGE] = b
    np.testing.assert_array_equal(
        handle.read(0, 8 * PAGE, version=v2).data, expect[: 8 * PAGE]
    )


def test_read_unpublished_version_fails():
    handle = make_cluster().session().create(4 * PAGE, PAGE)
    with pytest.raises(ValueError, match="not yet published"):
        handle.read(0, PAGE, version=1)


def test_unaligned_write_rejected():
    handle = make_cluster().session().create(4 * PAGE, PAGE)
    with pytest.raises(ValueError, match="page-aligned"):
        handle.write(np.zeros(PAGE, np.uint8), 3)


def test_metadata_sharing_between_versions():
    """COW weaving shares all unmodified subtrees (paper §III.C)."""
    cluster = make_cluster()
    handle = cluster.session().create(1024 * PAGE, PAGE)
    handle.write(np.ones(1024 * PAGE, np.uint8), 0)
    n_after_full = cluster.metadata.total_nodes()
    handle.write(np.ones(PAGE, np.uint8), 512 * PAGE)
    n_after_patch = cluster.metadata.total_nodes()
    # one-page patch creates exactly the root-to-leaf path: log2(1024)+1 nodes
    assert n_after_patch - n_after_full == 11
    assert n_after_patch - n_after_full == count_write_nodes(1024, 512, 1)


def test_page_replication_survives_provider_failure():
    cluster = make_cluster(n_data_providers=4, page_replication=2)
    handle = cluster.session().create(8 * PAGE, PAGE)
    payload = np.arange(8 * PAGE, dtype=np.uint8)
    v = handle.write(payload, 0)
    # kill the primary of some page: every page must still be readable
    cluster.provider_manager.fail_provider(0)
    np.testing.assert_array_equal(handle.read(0, 8 * PAGE, version=v).data, payload)


def test_metadata_replication_survives_shard_failure():
    cluster = make_cluster(n_metadata_providers=4, metadata_replication=2)
    handle = cluster.session().create(8 * PAGE, PAGE)
    payload = np.arange(8 * PAGE, dtype=np.uint8)
    v = handle.write(payload, 0)
    cluster.metadata.fail_shard(1)
    np.testing.assert_array_equal(handle.read(0, 8 * PAGE, version=v).data, payload)


def test_gc_keeps_reachable_shared_pages():
    cluster = make_cluster()
    handle = cluster.session().create(16 * PAGE, PAGE)
    base = np.ones(16 * PAGE, np.uint8)
    handle.write(base, 0)  # v1
    patch = np.full(PAGE, 5, np.uint8)
    handle.write(patch, 4 * PAGE)  # v2 shares 15 pages with v1
    nodes_freed, pages_freed = cluster.gc(handle.blob_id, keep_versions=[2])
    assert pages_freed == 1  # only v1's overwritten page dies
    assert nodes_freed > 0  # v1's root path dies
    expect = base.copy()
    expect[4 * PAGE : 5 * PAGE] = patch
    np.testing.assert_array_equal(handle.read(0, 16 * PAGE, version=2).data, expect)


def test_elastic_provider_join():
    cluster = make_cluster(n_data_providers=2)
    handle = cluster.session().create(8 * PAGE, PAGE)
    handle.write(np.ones(4 * PAGE, np.uint8), 0)
    new_pid = cluster.add_data_provider()
    handle.write(np.ones(4 * PAGE, np.uint8), 4 * PAGE)
    # the new provider picked up load (least-loaded placement)
    assert cluster.provider_manager.get_provider(new_pid).n_pages > 0


def test_version_manager_recovery_with_orphans():
    cluster = make_cluster()
    handle = cluster.session().create(8 * PAGE, PAGE)
    blob = handle.blob_id
    handle.write(np.ones(PAGE, np.uint8), 0)  # v1 complete
    # simulate a writer that got v2 assigned and crashed before reporting
    cluster.version_manager.assign_version(blob, 2, 1)
    handle.write(np.ones(PAGE, np.uint8), 4 * PAGE)  # v3 complete
    from repro.core import VersionManager

    vm2, orphans = VersionManager.recover(cluster.version_manager.journal)
    assert vm2.latest_published(blob) == 1  # publish stops before the orphan
    assert orphans[blob] == [2]
    # v3 completed: it publishes as soon as the orphan is resolved
    vm2.report_success(blob, 2)
    assert vm2.latest_published(blob) == 3


# ----------------------------- property tests --------------------------------


@st.composite
def patch_sequences(draw):
    n_pages = draw(st.sampled_from([8, 16, 32]))
    n_writes = draw(st.integers(min_value=1, max_value=8))
    writes = []
    for _ in range(n_writes):
        off = draw(st.integers(min_value=0, max_value=n_pages - 1))
        size = draw(st.integers(min_value=1, max_value=n_pages - off))
        fill = draw(st.integers(min_value=1, max_value=255))
        writes.append((off, size, fill))
    return n_pages, writes


@settings(max_examples=30, deadline=None)
@given(patch_sequences())
def test_serializability_reads_equal_prefix_of_patches(seq):
    """Paper §II: READ of version v == successive application of the first v
    patches to the all-zero string — for EVERY published version."""
    n_pages, writes = seq
    handle = make_cluster().session().create(n_pages * PAGE, PAGE)
    oracle = np.zeros(n_pages * PAGE, dtype=np.uint8)
    snapshots = [oracle.copy()]
    for off, size, fill in writes:
        buf = np.full(size * PAGE, fill, dtype=np.uint8)
        handle.write(buf, off * PAGE)
        oracle[off * PAGE : (off + size) * PAGE] = buf
        snapshots.append(oracle.copy())
    for v, snap in enumerate(snapshots):
        got = handle.read(0, n_pages * PAGE, version=v).data
        np.testing.assert_array_equal(got, snap)


@settings(max_examples=30, deadline=None)
@given(patch_sequences())
def test_border_links_point_to_latest_intersecting_version(seq):
    """compute_border_links must weave to the most recent intersecting patch."""
    n_pages, writes = seq
    intervals = {}

    for v, (off, size, _) in enumerate(writes, start=1):

        def version_of_segment(o, s):
            best = ZERO_VERSION
            for pv, (po, ps) in intervals.items():
                if po < o + s and o < po + ps:
                    best = max(best, pv)
            return best

        links = compute_border_links(n_pages, off, size, version_of_segment)
        for link in links:
            # the missing child never intersects the current patch
            assert not (link.child_offset < off + size and off < link.child_offset + link.child_size)
            assert link.child_version == version_of_segment(link.child_offset, link.child_size)
        intervals[v] = (off, size)


def test_unaligned_write_read_modify_write():
    handle = make_cluster().session().create(16 * PAGE, PAGE)
    base = np.arange(16 * PAGE, dtype=np.uint8)
    handle.write(base, 0)
    patch = np.full(PAGE, 200, np.uint8)
    off = 3 * PAGE + 17  # crosses two pages, unaligned both sides
    v = handle.write_unaligned(patch, off)
    expect = base.copy()
    expect[off : off + PAGE] = patch
    got = handle.read(0, 16 * PAGE, version=v).data
    np.testing.assert_array_equal(got, expect)
    # the pre-patch version is untouched (COW)
    np.testing.assert_array_equal(
        handle.read(0, 16 * PAGE, version=v - 1).data, base
    )
