"""Validate the trip-count-aware HLO cost parser against ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo, normalize_cost_analysis


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_match_xla():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    fn = lambda x, y: x @ y
    compiled = jax.jit(fn).lower(a, b).compile()
    got = analyze_hlo(compiled.as_text())
    expect = 2 * 128 * 256 * 64
    assert got.flops == expect
    xla = normalize_cost_analysis(compiled.cost_analysis()).get("flops", 0)
    if xla and xla > 0:
        np.testing.assert_allclose(got.flops, xla, rtol=0.01)


def test_scan_body_flops_multiplied_by_trip_count():
    L = 8
    w = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def fn(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, ws)
        return h

    text = _compiled_text(fn, w, x)
    got = analyze_hlo(text)
    expect = L * 2 * 4 * 64 * 64
    # the parser must count the while body L times (allow fusion slack)
    assert got.flops >= expect * 0.99, (got.flops, expect)
    assert got.flops <= expect * 1.5, (got.flops, expect)
    assert any(t == L for t in got.while_trips.values()), got.while_trips


def test_nested_scan_trip_counts_multiply():
    Lo, Li = 3, 5
    w = jax.ShapeDtypeStruct((Lo, Li, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 32), jnp.float32)

    def fn(ws, x):
        def outer(h, w_outer):
            def inner(hh, w):
                return hh @ w, None

            h2, _ = jax.lax.scan(inner, h, w_outer)
            return h2, None

        h, _ = jax.lax.scan(outer, x, ws)
        return h

    got = analyze_hlo(_compiled_text(fn, w, x))
    expect = Lo * Li * 2 * 2 * 32 * 32
    assert got.flops >= expect * 0.99
    assert got.flops <= expect * 1.6


def test_bytes_scale_with_trip_count():
    L = 16
    w = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def fn(ws, x):
        def body(h, w):
            return h @ w, None

        return jax.lax.scan(body, x, ws)[0]

    got = analyze_hlo(_compiled_text(fn, w, x))
    # each iteration must at least read its (128,128) fp32 weight slice
    assert got.bytes >= L * 128 * 128 * 4
