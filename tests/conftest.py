"""Test-suite bootstrap.

The property tests import :mod:`hypothesis`, which is not part of the baked
container image (and installing packages is off-limits). When the real
library is absent we register a minimal, deterministic stand-in that supports
the subset used here — ``given``/``settings`` decorators and the
``integers``/``sampled_from``/``composite`` strategies — drawing a fixed
number of pseudo-random examples per test. With hypothesis installed, the
stub steps aside entirely.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

try:  # pragma: no cover - exercised only when hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example(self, rng: random.Random):
            return self._draw_fn(rng)

    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def composite(fn):
        def build(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda strat: strat.example(rng), *args, **kwargs)

            return _Strategy(draw_fn)

        return build

    def given(*given_args, **given_kwargs):
        def decorate(fn):
            # Like real hypothesis, positional strategies fill the RIGHTMOST
            # parameters (leftmost ones stay available for pytest fixtures).
            params = list(inspect.signature(fn).parameters.values())
            n_pos = len(given_args)
            drawn_names = [p.name for p in params[len(params) - n_pos :]]
            remaining = params[: len(params) - n_pos]
            remaining = [p for p in remaining if p.name not in given_kwargs]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 20)
                seed0 = zlib.crc32(fn.__name__.encode())
                for i in range(n):
                    rng = random.Random(seed0 + i)
                    drawn_kw = dict(zip(drawn_names, (s.example(rng) for s in given_args)))
                    drawn_kw.update({k: s.example(rng) for k, s in given_kwargs.items()})
                    fn(*args, **kwargs, **drawn_kw)

            # Hide the drawn parameters from pytest's fixture resolution.
            wrapper.__signature__ = inspect.Signature(remaining)
            del wrapper.__wrapped__
            wrapper.hypothesis_stub = True
            return wrapper

        return decorate

    def settings(max_examples=20, deadline=None, **_ignored):
        def decorate(fn):
            fn._stub_max_examples = max_examples
            return fn

        return decorate

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.sampled_from = sampled_from
    strategies.composite = composite
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies


# -- lockwatch integration ----------------------------------------------------
# With REPRO_LOCKWATCH=1 every core lock is a WatchedLock reporting to the
# process-global acquisition graph; these fixtures (no-ops otherwise) install
# the join-under-lock hooks once and fail any test that recorded a violation.

import pytest  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _lockwatch_hooks():
    from repro.analysis import lockwatch

    if lockwatch.enabled():
        lockwatch.install_blocking_hooks()
    yield


@pytest.fixture(autouse=True)
def _lockwatch_assert_clean():
    yield
    from repro.analysis import lockwatch

    if lockwatch.enabled():
        lockwatch.watch().assert_clean(reset=True)
